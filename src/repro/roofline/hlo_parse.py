"""Trip-count-aware HLO accounting.

XLA's ``compiled.cost_analysis()`` (and any naive text scrape) counts a
``while`` body ONCE — but our programs put almost everything inside scans
(layer-group scan x G, grad-accumulation scan x ga, attention q-chunk scan,
loss token-chunk scan).  FSDP all-gathers and TP all-reduces live *inside*
the layer scan, so collective bytes would be undercounted by ~Gx.

This parser:
  1. splits the optimised HLO text into computations,
  2. finds each ``while``'s body/condition regions and extracts the trip
     count from the condition's comparison constant,
  3. propagates nested trip multipliers from ENTRY down,
  4. sums collective wire bytes x multiplier (ring-cost conversions as in
     ``analysis.collective_bytes_from_hlo``).

Verified against hand-built scan programs in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.roofline.analysis import _COLL_RE, _group_size, _shape_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", re.DOTALL
)
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_alias = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(line) or _COMP_HDR.match(stripped)
            if m and (line.rstrip().endswith("{") or stripped.endswith("{")):
                cur = m.group(1)
                comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry_alias = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    if entry_alias is not None:
        comps["__entry__"] = comps[entry_alias]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = [int(m.group(1)) for l in cond_lines for m in _CONST_RE.finditer(l)]
    return max(consts) if consts else 1


def computation_multipliers(hlo: str) -> Tuple[Dict[str, List[str]], Dict[str, float]]:
    comps = split_computations(hlo)
    entry = "__entry__"
    mult: Dict[str, float] = defaultdict(float)
    if entry not in comps:
        return comps, {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # iterate to fixpoint over the call graph (while bodies, calls, fusions)
    for _ in range(32):
        changed = False
        for name, lines in comps.items():
            base = mult.get(name, 0.0)
            if base <= 0:
                continue
            for l in lines:
                mw = _WHILE_RE.search(l)
                if mw:
                    cond, body = mw.group(1), mw.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    for target in (body, cond):
                        want = base * trips
                        if mult.get(target, 0.0) < want:
                            mult[target] = want
                            changed = True
                    continue
                mc = _CALL_RE.search(l)
                if mc:
                    target = mc.group(1)
                    if mult.get(target, 0.0) < base:
                        mult[target] = base
                        changed = True
        if not changed:
            break
    out = {name: mult.get(name, 1.0) for name in comps}
    return comps, out


def collective_bytes_trip_aware(
    hlo: str, total_devices: int, pod_group_size: Optional[int] = None
) -> Dict[str, float]:
    """Per-chip wire bytes by kind, with while-loop trip multipliers."""
    comps, mult = computation_multipliers(hlo)
    out: Dict[str, float] = defaultdict(float)
    seen_entry = set()
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        k = mult.get(name, 1.0)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            shape_str, op = m.group(1), m.group(2)
            rb = _shape_bytes(shape_str)
            # XLA *CPU* promotes bf16 all-reduces to f32 (to_apply=..._promoted)
            # because the CPU backend lacks bf16 reduction math; the TPU target
            # reduces bf16 natively, so count promoted reduces at bf16 width.
            if "_promoted" in line:
                rb *= 0.5
            W = _group_size(line, total_devices)
            if W <= 1:
                continue
            if op == "all-reduce":
                wire = 2 * (W - 1) / W * rb
            elif op == "all-gather":
                wire = (W - 1) / W * rb
            elif op == "reduce-scatter":
                wire = (W - 1) * rb
            elif op == "all-to-all":
                wire = (W - 1) / W * rb
            else:
                wire = rb
            out[op] += wire * k
            link = "dcn" if (pod_group_size and W == pod_group_size) else "ici"
            out[link] += wire * k
    out["total"] = sum(v for kk, v in out.items() if kk not in ("ici", "dcn", "total"))
    return dict(out)
