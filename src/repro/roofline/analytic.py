"""Analytic per-cell FLOP / HBM-byte models (documented napkin math).

Why analytic: XLA's cost analysis counts while-loop bodies once (verified in
tests), so for scan-over-layers programs it undercounts by ~num_layers x.
Collectives are recovered trip-aware from the HLO (hlo_parse.py); for compute
and HBM traffic we use explicit formulas — standard practice (the 6ND family)
extended with attention's quadratic term, remat recompute, optimizer traffic
and KV-cache reads.  EXPERIMENTS.md §Roofline states the formulas; the raw
(undercounting) cost_analysis numbers stay in the per-cell JSON for
comparison.

Conventions: per-chip, per-step, bf16 weights/activations, fp32 optimizer.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.attention_free:
        return 0
    if cfg.attn_layer_period:
        return cfg.num_layers // cfg.attn_layer_period
    return cfg.num_layers + cfg.encoder_layers


def _active_params(cfg: ModelConfig) -> int:
    n = cfg.param_count()
    if cfg.moe is None:
        return n
    m = cfg.moe
    moe_layers = cfg.num_layers // m.layer_period
    expert_params = moe_layers * m.num_experts * (3 if cfg.mlp_glu else 2) \
        * cfg.d_model * m.d_ff_expert
    active_expert = expert_params * (m.num_experts_per_tok / m.num_experts)
    return int(n - expert_params + active_expert)


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Forward score+PV flops (causal halves the full S^2)."""
    if cfg.attention_free:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    la = _attn_layers(cfg)
    if shape.is_decode:
        # one token attends to the whole cache (window-limited for SWA)
        kv = min(cfg.sliding_window or S, S)
        return 4.0 * B * kv * cfg.num_heads * hd * la
    per_layer = 2.0 * B * S * S * cfg.num_heads * hd  # qk^T + pv, causal 1/2
    window = cfg.sliding_window
    if window and 0 < window < S:
        local = 2.0 * B * S * window * cfg.num_heads * hd * 2  # full window band
        if cfg.local_global_period:
            n_local = la // 2
            return per_layer * (la - n_local) + local * n_local
        return local * la
    return per_layer * la


def cell_flops_per_chip(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = B * S
        matmul = 2.0 * n_active * tokens
        attn = attention_flops(cfg, shape)
        fwd = matmul + attn
        total = 4.0 * fwd               # fwd + bwd(2x) + remat fwd(1x)
    elif shape.kind == "prefill":
        tokens = B * S
        total = 2.0 * n_active * tokens + attention_flops(cfg, shape)
    else:  # decode: one token per sequence
        total = 2.0 * n_active * B + attention_flops(cfg, shape)
    return {
        "model_flops": (2.0 if shape.kind != "train" else 6.0) * n_active *
                       (B if shape.is_decode else B * S),
        "hlo_flops_est": total,
        "per_chip": total / chips,
        "active_params": float(n_active),
    }


def cell_hbm_bytes_per_chip(
    cfg: ModelConfig, shape: ShapeConfig, chips: int, grad_accum: int = 1
) -> Dict[str, float]:
    """HBM traffic model (bf16=2B, fp32=4B), per chip per step.

    train:  weights 3 passes per microbatch (fwd, remat-fwd, bwd) +
            grads f32 read/write + optimizer (m,v,master r/w + param write) +
            saved activations (layer inputs) write+read.
    prefill: weights once + activations once + cache write.
    decode:  weights once (batch amortises) + full KV/state read + tiny IO.
    """
    B, S = shape.global_batch, shape.seq_len
    n = cfg.param_count()
    n_active = _active_params(cfg)
    d = cfg.d_model
    L = cfg.num_layers + cfg.encoder_layers
    w_bytes = 2.0 * n
    act_layer = 2.0 * B * S * d            # one bf16 (B,S,d) tensor
    if shape.kind == "train":
        weights = 3.0 * grad_accum * 2.0 * n_active   # active path touched
        grads = (4.0 + 4.0) * n                       # f32 write+read
        optim = 5.0 * 4.0 * n                         # m,v,master r/w-ish + p
        acts = 2.0 * 2.0 * L * act_layer              # save+load layer inputs
        intra = 8.0 * L * act_layer * grad_accum / grad_accum  # fused interm.
        total = weights + grads + optim + acts + intra
    elif shape.kind == "prefill":
        kvb = 2.0 * 2.0 * B * S * cfg.num_kv_heads * cfg.resolved_head_dim \
            * _attn_layers(cfg)
        total = 2.0 * n_active + 6.0 * L * act_layer + kvb
    else:
        kv = min(cfg.sliding_window or S, S)
        kvb = 2.0 * 2.0 * B * kv * cfg.num_kv_heads * cfg.resolved_head_dim \
            * _attn_layers(cfg)
        ssmb = 0.0
        if cfg.ssm is not None:
            s_layers = (cfg.num_layers - _attn_layers(cfg)) if not cfg.attention_free \
                else cfg.num_layers
            ssmb = 2.0 * 4.0 * B * cfg.ssm.expand * d * cfg.ssm.state_size * s_layers
        total = 2.0 * n_active + kvb + ssmb
    return {"per_chip": total / chips, "weights_bytes": w_bytes}
