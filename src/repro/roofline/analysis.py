"""Roofline accounting from the compiled dry-run artifact (deliverable g).

Three terms per (arch x shape x mesh), all in seconds-per-step-per-chip:

    compute    = HLO_FLOPs            / peak_FLOP/s          (197e12, bf16 v5e)
    memory     = HLO_bytes_accessed   / HBM_bw               (819e9  B/s)
    collective = wire_bytes_per_chip  / ICI_link_bw          (50e9   B/s; DCN
                                                              12.5e9 for pod-
                                                              spanning groups)

``cost_analysis()`` on an SPMD-partitioned executable reports the per-device
program, so flops/bytes are already per-chip.  Collective bytes are NOT in
cost_analysis: we parse the optimised HLO and convert each collective's
result shape into per-chip wire bytes using ring-algorithm costs:

    all-reduce       2 (W-1)/W x result
    all-gather         (W-1)/W x result          (result = gathered buffer)
    reduce-scatter     (W-1)   x result          (result = 1/W shard)
    all-to-all         (W-1)/W x result
    collective-permute           result
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Optional

HW = dict(
    chip="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dcn_bw=12.5e9,
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes_from_hlo(
    hlo_text: str, total_devices: int, pod_group_size: Optional[int] = None
) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind + ici/dcn split.

    ``pod_group_size``: group sizes equal to the pod count are attributed to
    the DCN (cross-pod) term.
    """
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        rb = _shape_bytes(shape_str)
        W = _group_size(line, total_devices)
        if W <= 1:
            continue
        if op == "all-reduce":
            wire = 2 * (W - 1) / W * rb
        elif op == "all-gather":
            wire = (W - 1) / W * rb
        elif op == "reduce-scatter":
            wire = (W - 1) * rb
        elif op == "all-to-all":
            wire = (W - 1) / W * rb
        else:  # collective-permute
            wire = rb
        out[op] += wire
        link = "dcn" if (pod_group_size and W == pod_group_size) else "ici"
        out[link] += wire
    out["total"] = sum(v for k, v in out.items() if k not in ("ici", "dcn", "total"))
    return dict(out)


def model_flops(param_count: int, tokens: int, kind: str,
                active_param_count: Optional[int] = None) -> float:
    """6·N·D for training, 2·N·D for inference (N = active params for MoE)."""
    n = active_param_count or param_count
    return (6.0 if kind == "train" else 2.0) * n * tokens


def roofline_terms(
    cost: Dict[str, float],
    coll: Dict[str, float],
    chips: int,
    model_fl: float,
) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / HW["peak_flops_bf16"]
    memory_s = bytes_accessed / HW["hbm_bw"]
    ici_s = coll.get("ici", 0.0) / HW["ici_bw"]
    dcn_s = coll.get("dcn", 0.0) / HW["dcn_bw"]
    collective_s = ici_s + dcn_s
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "collective_ici_s": ici_s,
        "collective_dcn_s": dcn_s,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "wire_bytes_per_chip": coll.get("total", 0.0),
        "model_flops_per_chip": model_fl / chips,
        "useful_flops_ratio": (model_fl / chips) / flops if flops else 0.0,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    terms["step_lower_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"], 1e-12
    )
    # fraction of the step bound that is *useful* model math — the MFU this
    # cell would achieve if it ran exactly at its binding roofline term
    terms["mfu_at_bound"] = (
        terms["model_flops_per_chip"] / HW["peak_flops_bf16"]
    ) / terms["step_lower_bound_s"]
    # how close the compiled program is to being compute-bound
    terms["roofline_fraction"] = terms["compute_s"] / terms["step_lower_bound_s"]
    return terms
