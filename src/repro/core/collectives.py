"""Manual collective algorithms — the paper's mechanisms as JAX primitives.

Each function runs *inside* ``shard_map`` (it uses ``lax.ppermute`` /
``lax.axis_index`` over a named mesh axis) and implements one of the
communication schedules the paper studies:

* ``ring_reduce_scatter`` / ``ring_all_gather`` / ``ring_all_reduce`` —
  Horovod-style ring-reduce (§3.3.2), the paper's winning mechanism.  The
  all-gather second phase is the paper's "second ring"; on TPU the ICI
  broadcast of that phase is the multicast analogue (§8.4).
* ``butterfly_all_reduce`` — butterfly mixing (§3.3.2): log2(W) stages, the
  *entire* buffer exchanged with the XOR partner each stage.
* ``rhd_all_reduce`` — Rabenseifner recursive halving/doubling [24]: the
  bandwidth-optimal cousin the paper cites; included beyond the paper's two
  host mechanisms.
* ``ps_reduce_scatter_gather`` — the parameter-server emulation: buckets are
  reduced onto *owner* shards (aggregation phase) and re-broadcast
  (distribution phase).  Ownership assignment — round-robin vs size-balanced
  (§9.1, Tables 7-8) — is chosen by the bucketing layer.
* ``hierarchical_all_reduce`` — pod-local reduce-scatter, cross-pod
  all-reduce over DCN, pod-local all-gather: the multi-pod schedule.

All ring/butterfly algorithms require the buffer length to be divisible by
the axis size; ``repro.core.bucketing.pack`` guarantees that.
"""
from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _ring_perm(W: int) -> List[Tuple[int, int]]:
    return [(i, (i + 1) % W) for i in range(W)]


# --------------------------------------------------------------------- ring
def ring_reduce_scatter(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """1-D ``x`` (len divisible by W) -> local reduced chunk (len/W).

    Chunk ``c`` starts at device ``c+1`` and travels the ring for W-1 hops,
    accumulating each device's contribution, ending at device ``c``.  At hop
    ``t`` device ``d`` therefore holds the chunk that started at ``d-t``,
    i.e. chunk ``c = d-t-1``.
    """
    W = axis_size
    if W == 1:
        return x
    d = lax.axis_index(axis_name)
    chunks = x.reshape(W, -1)
    perm = _ring_perm(W)
    buf = jnp.take(chunks, jnp.mod(d - 1, W), axis=0)

    def step(buf, t):
        buf = lax.ppermute(buf, axis_name, perm)
        c = jnp.mod(d - t - 1, W)
        return buf + jnp.take(chunks, c, axis=0), None

    buf, _ = lax.scan(step, buf, jnp.arange(1, W))
    return buf


def ring_all_gather(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Local chunk (n,) -> full buffer (W*n,) via W-1 ring hops."""
    W = axis_size
    if W == 1:
        return x
    d = lax.axis_index(axis_name)
    perm = _ring_perm(W)
    out = jnp.zeros((W,) + x.shape, x.dtype)
    out = out.at[d].set(x)

    def step(carry, t):
        piece, out = carry
        piece = lax.ppermute(piece, axis_name, perm)
        out = out.at[jnp.mod(d - t, W)].set(piece)
        return (piece, out), None

    (_, out), _ = lax.scan(step, (x, out), jnp.arange(1, W))
    return out.reshape((-1,) + x.shape[1:])


def ring_all_reduce(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    return ring_all_gather(ring_reduce_scatter(x, axis_name, axis_size), axis_name, axis_size)


def ring_all_reduce_multicast_phase2(
    x: jax.Array, axis_name: str, axis_size: int
) -> jax.Array:
    """Ring-reduce first ring + *multicast* second phase (§8.4): the gather
    is done with the fabric's native broadcast (XLA all-gather over ICI)
    instead of a second ppermute ring."""
    chunk = ring_reduce_scatter(x, axis_name, axis_size)
    return lax.all_gather(chunk, axis_name, tiled=True)


# ----------------------------------------------------------------- butterfly
def butterfly_all_reduce(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Butterfly mixing: at stage s exchange the FULL buffer with partner
    ``d xor 2^s`` and add.  log2(W) stages; W must be a power of two."""
    W = axis_size
    assert W & (W - 1) == 0, "butterfly requires power-of-two axis size"
    s = 1
    while s < W:
        perm = [(i, i ^ s) for i in range(W)]
        x = x + lax.ppermute(x, axis_name, perm)
        s <<= 1
    return x


# -------------------------------------------------------------- rabenseifner
def rhd_all_reduce(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Rabenseifner: recursive-halving reduce-scatter then recursive-doubling
    all-gather.  Bandwidth 2(W-1)/W * n like ring, but log2(W) latency."""
    W = axis_size
    assert W & (W - 1) == 0, "rhd requires power-of-two axis size"
    if W == 1:
        return x
    d = lax.axis_index(axis_name)
    n = x.size

    # --- reduce-scatter by halving ------------------------------------------
    # working set: a window of x, halved each stage.  Represent the window
    # implicitly: at stage s the buffer length is n >> (s+1).
    buf = x
    s = 1
    while s < W:
        half = buf.size // 2
        lo, hi = buf[:half], buf[half:]
        partner_has_high = (d & s) == 0   # we keep low if bit clear
        perm = [(i, i ^ s) for i in range(W)]
        # send the half we are NOT keeping; receive partner's matching half
        outgoing = jnp.where(partner_has_high, hi, lo)
        incoming = lax.ppermute(outgoing, axis_name, perm)
        buf = jnp.where(partner_has_high, lo + incoming, hi + incoming)
        s <<= 1

    # --- all-gather by doubling ----------------------------------------------
    s = W >> 1
    while s >= 1:
        perm = [(i, i ^ s) for i in range(W)]
        other = lax.ppermute(buf, axis_name, perm)
        keep_low = (d & s) == 0
        # device with bit clear holds the low half of the merged window
        buf = jnp.where(keep_low, jnp.concatenate([buf, other]),
                        jnp.concatenate([other, buf]))
        s >>= 1
    return buf


# ------------------------------------------------------------------ PS model
def ps_reduce_scatter_gather(
    x: jax.Array, axis_name: str, axis_size: int
) -> jax.Array:
    """Parameter-server emulation: aggregation = reduce onto owner shards
    (XLA reduce-scatter — the in-network-aggregation analogue, since the ICI
    reduces hop-by-hop), distribution = broadcast back (all-gather — the
    multicast analogue).  Bucket->owner placement is decided upstream by
    reordering ``x`` (see bucketing.assign_owners)."""
    chunk = lax.psum_scatter(x.reshape(axis_size, -1), axis_name, scatter_dimension=0, tiled=False)
    return lax.all_gather(chunk, axis_name, tiled=False).reshape(x.shape)


# ---------------------------------------------------------------- hierarchical
def hierarchical_all_reduce(
    x: jax.Array,
    inner_axis: str,
    inner_size: int,
    outer_axis: str,
    use_ring_inner: bool = True,
) -> jax.Array:
    """Multi-pod schedule: reduce-scatter inside the pod (fast ICI), a single
    all-reduce of the 1/W-sized shard across pods (slow DCN), then all-gather
    inside the pod.  Cross-pod traffic shrinks by the pod size — the paper's
    'keep the scarce link off the critical path' lesson applied to DCN."""
    if use_ring_inner:
        chunk = ring_reduce_scatter(x, inner_axis, inner_size)
        chunk = lax.psum(chunk, outer_axis)
        return ring_all_gather(chunk, inner_axis, inner_size)
    chunk = lax.psum_scatter(x.reshape(inner_size, -1), inner_axis, scatter_dimension=0)
    chunk = lax.psum(chunk, outer_axis)
    return lax.all_gather(chunk, inner_axis).reshape(x.shape)


# ------------------------------------------------------------------ registry
ALL_REDUCE_FNS = {
    "ring": ring_all_reduce,
    "ring+multicast": ring_all_reduce_multicast_phase2,
    "butterfly": butterfly_all_reduce,
    "rabenseifner": rhd_all_reduce,
    "ps": ps_reduce_scatter_gather,
    "psum": lambda x, axis_name, axis_size: lax.psum(x, axis_name),
}
