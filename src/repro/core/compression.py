"""Gradient compression (§10): int8 quantised collectives + top-k error feedback.

The paper's position is that compression "is analogous to using a smaller
CNN".  We implement it as a first-class option of the gradient-sync layer so
the roofline collective term can actually be bought down:

* ``int8``  — blockwise symmetric quantisation; the ring reduce-scatter hops
  carry int8 + one fp32 scale per block (4.05x wire-size reduction at
  block=128 vs bf16), dequant-accumulate-requant at every hop (the error of
  re-quantising k partial sums grows O(log W); fine for SGD-class updates).
* ``topk``  — error-feedback top-k sparsification (Deep Gradient Compression
  [20]): each shard sends its k largest-magnitude entries; the residual is
  fed back into the next step locally, making the compressor unbiased over
  time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives as C

QBLOCK = 128


# ------------------------------------------------------------------- int8
def quantize_int8(x: jax.Array, block: int = QBLOCK) -> Tuple[jax.Array, jax.Array]:
    """x: 1-D (len divisible by block) -> (int8 values, fp32 per-block scales)."""
    xb = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)


def int8_ring_all_reduce(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Ring all-reduce whose wire format is int8 (+fp32 block scales).

    Reduce-scatter phase: the in-flight chunk is dequantised, the local
    contribution added, and the sum re-quantised before the next hop.
    All-gather phase: the final chunks travel once, still int8.
    """
    W = axis_size
    if W == 1:
        return x
    d = lax.axis_index(axis_name)
    chunks = x.reshape(W, -1)
    perm = [(i, (i + 1) % W) for i in range(W)]

    q0, s0 = quantize_int8(jnp.take(chunks, jnp.mod(d - 1, W), axis=0))

    def rs_step(carry, t):
        q, s = carry
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        c = jnp.mod(d - t - 1, W)
        acc = dequantize_int8(q, s) + jnp.take(chunks, c, axis=0).astype(jnp.float32)
        return quantize_int8(acc), None

    (q, s), _ = lax.scan(rs_step, (q0, s0), jnp.arange(1, W))
    # all-gather phase (wire stays int8)
    qg = C.ring_all_gather(q.reshape(-1), axis_name, axis_size).reshape(W * q.shape[0], QBLOCK)
    sg = C.ring_all_gather(s.reshape(-1), axis_name, axis_size).reshape(-1, 1)
    return dequantize_int8(qg, sg).astype(x.dtype)


# ------------------------------------------------------------------- top-k
def topk_compress(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Return (values, indices) of the k largest-magnitude entries of 1-D x."""
    _, idx = lax.top_k(jnp.abs(x), k)
    return x[idx], idx


def topk_ef_all_reduce(
    x: jax.Array,
    residual: jax.Array,
    axis_name: str,
    axis_size: int,
    k_fraction: float = 0.01,
) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback top-k all-reduce.

    Returns (reduced approximation of psum(x), new residual).  Wire cost is
    ``2 * k * W`` words instead of ``2n(W-1)/W`` for the ring.
    """
    g = x.astype(jnp.float32) + residual
    k = max(1, int(x.size * k_fraction))
    vals, idx = topk_compress(g, k)
    new_residual = g.at[idx].set(0.0)
    # exchange (vals, idx) with everyone; scatter-add into a dense buffer
    all_vals = lax.all_gather(vals, axis_name)          # (W, k)
    all_idx = lax.all_gather(idx, axis_name)            # (W, k)
    dense = jnp.zeros((x.size,), jnp.float32)
    dense = dense.at[all_idx.reshape(-1)].add(all_vals.reshape(-1))
    return dense.astype(x.dtype), new_residual
