"""GradSync — the paper's communication mechanisms as one composable module.

Usage (inside a ``shard_map`` over the data-parallel axes)::

    sync = GradSync(GradSyncConfig(strategy="ring"), grads_example)
    reduced, new_residuals = sync(local_grads, axis_sizes={"data": 16}, residuals=res)

The strategy names correspond 1:1 to the paper's mechanisms (§3, §8):

===================  ========================================================
``psum``             XLA's native all-reduce (the fabric's in-network
                     aggregation — the TPU baseline).
``ring``             Horovod ring-reduce, manual ppermute schedule.
``ring+multicast``   ring first phase + fabric broadcast second phase (§8.4).
``butterfly``        butterfly mixing (full-buffer XOR exchange, log2 W).
``rabenseifner``     recursive halving/doubling (cited, beyond-paper).
``ps``               parameter-server emulation: per-owner regions,
                     reduce-scatter onto owners + all-gather.  Round-robin
                     owner assignment reproduces TF's byte imbalance
                     (Table 7) as padding waste; size_balanced fixes it
                     (Table 8).
``hierarchical``     pod-local ring reduce-scatter + cross-pod psum +
                     pod-local all-gather (multi-pod schedule).
===================  ========================================================

Compression (§10) composes with any strategy: ``int8`` swaps the ring for a
quantised ring; ``topk`` performs error-feedback sparsified exchange.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bucketing as B
from repro.core import collectives as C
from repro.core import compression as Z

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "psum"
    axis_name: str = "data"
    pod_axis: str = ""                 # non-empty => also reduce across pods
    bucket_bytes: int = 32 * 1024 * 1024
    max_message_bytes: int = 0         # 0 => no message chunking (§9.2 off)
    assignment: str = "round_robin"    # PS owner placement (§9.1)
    num_owners: int = 0                # 0 => axis size
    compression: str = ""              # "" | "int8" | "topk"
    topk_fraction: float = 0.01
    average: bool = True


class GradSync:
    """Precomputes bucketing/assignment for a fixed gradient structure."""

    def __init__(self, cfg: GradSyncConfig, grads_example: PyTree):
        self.cfg = cfg
        self.leaves = B.leaves_of(grads_example)
        self.treedef = jax.tree.structure(grads_example)
        self.buckets = B.build_buckets(self.leaves, cfg.bucket_bytes)
        if cfg.max_message_bytes:
            self.buckets = B.chunk_buckets(self.buckets, self.leaves, cfg.max_message_bytes)
        sizes = [l.size for l in self.leaves]
        self.owners = B.assign_owners(
            sizes, cfg.num_owners or 1, cfg.assignment
        )

    # -- stateful compressor support -----------------------------------------
    def init_residuals(self) -> Optional[List[jax.Array]]:
        if self.cfg.compression != "topk":
            return None
        return [jnp.zeros((self._padded_size(b),), jnp.float32) for b in self.buckets]

    def _padded_size(self, bucket: B.Bucket) -> int:
        n = sum(self.leaves[i].size for i in bucket.leaf_ids)
        align = 512  # lcm-ish alignment: covers ring(W<=512) and int8 blocks
        return n + ((-n) % align)

    # -- main entry ------------------------------------------------------------
    def __call__(
        self,
        grads: PyTree,
        axis_sizes: Dict[str, int],
        residuals: Optional[List[jax.Array]] = None,
    ) -> Tuple[PyTree, Optional[List[jax.Array]]]:
        cfg = self.cfg
        W = axis_sizes[cfg.axis_name]
        pod = axis_sizes.get(cfg.pod_axis, 1) if cfg.pod_axis else 1
        flat = jax.tree.leaves(grads)
        out_flat: List[Optional[jax.Array]] = [None] * len(flat)
        new_residuals: Optional[List[jax.Array]] = [] if residuals is not None else None

        if cfg.strategy == "ps":
            reduced = self._ps_sync(flat, W)
            for i, g in reduced.items():
                out_flat[i] = g
        else:
            # int8 rings need each ring chunk (len/W) divisible by the quant
            # block, so align to W * QBLOCK
            align = 512 if cfg.compression != "int8" else max(512, W * Z.QBLOCK)
            for bi, bucket in enumerate(self.buckets):
                buf = B.pack(flat, bucket, align=align)
                res = residuals[bi] if residuals is not None else None
                buf, res = self._reduce_buffer(buf, res, W)
                if new_residuals is not None:
                    new_residuals.append(res)
                for i, g in B.unpack(buf, bucket, self.leaves).items():
                    out_flat[i] = g

        denom = W * pod if cfg.average else 1
        if denom != 1:
            out_flat = [g / denom for g in out_flat]
        out_flat = [g.astype(l.dtype) for g, l in zip(out_flat, self.leaves)]
        return jax.tree.unflatten(self.treedef, out_flat), new_residuals

    # -- single packed buffer --------------------------------------------------
    def _reduce_buffer(self, buf, residual, W):
        cfg = self.cfg
        if cfg.compression == "int8":
            red = Z.int8_ring_all_reduce(buf, cfg.axis_name, W)
        elif cfg.compression == "topk":
            red, residual = Z.topk_ef_all_reduce(
                buf, residual, cfg.axis_name, W, cfg.topk_fraction
            )
        elif cfg.strategy == "hierarchical":
            red = C.hierarchical_all_reduce(buf, cfg.axis_name, W, cfg.pod_axis or "pod")
        else:
            red = C.ALL_REDUCE_FNS[cfg.strategy](buf, cfg.axis_name, W)
        if cfg.pod_axis and cfg.strategy != "hierarchical":
            red = jax.lax.psum(red, cfg.pod_axis)
        return red, residual

    # -- PS emulation ------------------------------------------------------------
    def _ps_sync(self, flat: Sequence[jax.Array], W: int) -> Dict[int, jax.Array]:
        """Pack per-owner regions (padded to the max owner load — round-robin
        assignment pays its imbalance as padding bandwidth), reduce-scatter
        onto owners, all-gather back."""
        cfg = self.cfg
        num_owners = cfg.num_owners or W
        owners = B.assign_owners(
            [l.size for l in self.leaves], num_owners, cfg.assignment
        )
        regions: List[List[int]] = [[] for _ in range(num_owners)]
        for i, o in enumerate(owners):
            regions[o].append(i)
        region_sizes = [sum(self.leaves[i].size for i in r) for r in regions]
        R = max(max(region_sizes), 1)
        R += (-R) % 8
        packed = []
        for r in regions:
            parts = [flat[i].reshape(-1).astype(jnp.float32) for i in r] or [
                jnp.zeros((0,), jnp.float32)
            ]
            buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            packed.append(jnp.pad(buf, (0, R - buf.size)))
        # owners live on shards 0..num_owners-1 of the axis; pad to W regions
        stack = jnp.stack(packed + [jnp.zeros((R,), packed[0].dtype)] * (W - num_owners))
        chunk = jax.lax.psum_scatter(stack, cfg.axis_name, scatter_dimension=0)
        full = jax.lax.all_gather(chunk, cfg.axis_name)
        out: Dict[int, jax.Array] = {}
        for o, r in enumerate(regions):
            off = 0
            for i in r:
                n = self.leaves[i].size
                out[i] = jax.lax.dynamic_slice_in_dim(full[o], off, n).reshape(
                    self.leaves[i].shape
                )
                off += n
        return out
