"""Gradient bucketing, parameter->owner assignment, and message chunking.

This is the host-side half of the paper's findings:

* §9.1 / Tables 7-8 — *parameter assignment*: TensorFlow's round-robin
  placement leaves some parameter servers holding 86-92% of the bytes
  (VGG16's fused FC layer).  ``assign_owners`` implements both round-robin
  and the size-balanced greedy assignment, and ``imbalance`` reports the
  min/max occupancy the paper tabulates.
* §9.2 — *message pipelining*: large parameters are split into fixed-size
  messages so a ring never serialises on one 5 Gb tensor.  ``chunk_buckets``
  splits packed buckets at ``max_message_bytes``.
* §8 — bucket-order = backprop order: gradients are emitted last-layer-first,
  so buckets are scheduled in reverse-layer order, letting the collective of
  bucket b overlap the backprop compute of bucket b+1 (XLA's latency-hiding
  scheduler does the overlap on real hardware; we expose the parallelism).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Leaf:
    path: str
    shape: Tuple[int, ...]
    size: int
    dtype: Any


@dataclasses.dataclass(frozen=True)
class Bucket:
    leaf_ids: Tuple[int, ...]
    bytes: int
    owner: int = -1                     # PS owner shard (-1: unowned)


def leaves_of(tree: PyTree) -> List[Leaf]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, x in flat:
        out.append(Leaf(jax.tree_util.keystr(path), tuple(x.shape), int(np.prod(x.shape or (1,))), x.dtype))
    return out


# ------------------------------------------------------------------ assignment
def assign_owners(
    sizes: Sequence[int], num_owners: int, policy: str = "round_robin"
) -> List[int]:
    """Map each parameter to an owner shard.

    ``round_robin`` reproduces TensorFlow's default heuristic (balanced in
    *count*, wildly unbalanced in *bytes* — Table 7); ``size_balanced`` is the
    greedy largest-first bin packing of §9.1/Table 8.
    """
    owners = [0] * len(sizes)
    if policy == "round_robin":
        for i in range(len(sizes)):
            owners[i] = i % num_owners
    elif policy == "size_balanced":
        load = [0] * num_owners
        for i in sorted(range(len(sizes)), key=lambda i: -sizes[i]):
            o = int(np.argmin(load))
            owners[i] = o
            load[o] += sizes[i]
    else:
        raise ValueError(policy)
    return owners


def imbalance(sizes: Sequence[int], owners: Sequence[int], num_owners: int):
    """(min%, max%, ideal%) of bytes per owner — the paper's Table 7 columns."""
    load = np.zeros(num_owners)
    for s, o in zip(sizes, owners):
        load[o] += s
    total = max(load.sum(), 1)
    return float(load.min() / total), float(load.max() / total), 1.0 / num_owners


# ------------------------------------------------------------------- buckets
def build_buckets(
    leaves: Sequence[Leaf],
    target_bytes: int = 32 * 1024 * 1024,
    reverse_layer_order: bool = True,
) -> List[Bucket]:
    """Greedy contiguous bucketing in (reverse) leaf order.

    Reverse order matches gradient-ready order during backprop, which is what
    lets bucket collectives pipeline with remaining backprop compute (§4).
    """
    order = list(range(len(leaves)))
    if reverse_layer_order:
        order = order[::-1]
    buckets: List[Bucket] = []
    cur: List[int] = []
    cur_bytes = 0
    for i in order:
        b = leaves[i].size * jnp.dtype(leaves[i].dtype).itemsize
        if cur and cur_bytes + b > target_bytes:
            buckets.append(Bucket(tuple(cur), cur_bytes))
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += b
    if cur:
        buckets.append(Bucket(tuple(cur), cur_bytes))
    return buckets


def chunk_buckets(buckets: List[Bucket], leaves: Sequence[Leaf],
                  max_message_bytes: int) -> List[Bucket]:
    """§9.2 message pipelining: re-split buckets that exceed the message size.

    Splitting happens at the packed-buffer level (``pack`` pads each bucket),
    so a single 5 Gb parameter becomes several messages on the wire.
    """
    out: List[Bucket] = []
    for b in buckets:
        if b.bytes <= max_message_bytes:
            out.append(b)
            continue
        # split leaf list greedily; oversized single leaves stay whole here and
        # are chunked inside pack() by the strategy (flat buffer split).
        cur, cur_bytes = [], 0
        for i in b.leaf_ids:
            lb = leaves[i].size * jnp.dtype(leaves[i].dtype).itemsize
            if cur and cur_bytes + lb > max_message_bytes:
                out.append(Bucket(tuple(cur), cur_bytes, b.owner))
                cur, cur_bytes = [], 0
            cur.append(i)
            cur_bytes += lb
        if cur:
            out.append(Bucket(tuple(cur), cur_bytes, b.owner))
    return out


# ---------------------------------------------------------------- pack/unpack
def pack(
    grads_flat: Sequence[jax.Array], bucket: Bucket, align: int,
    dtype=jnp.float32,
) -> jax.Array:
    """Concatenate a bucket's leaves into one 1-D buffer padded to ``align``.

    Cast to ``dtype`` (reduction dtype) — gradient trees mix bf16/f32 leaves.
    """
    parts = [grads_flat[i].reshape(-1).astype(dtype) for i in bucket.leaf_ids]
    buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
    pad = (-buf.size) % align
    if pad:
        buf = jnp.concatenate([buf, jnp.zeros((pad,), buf.dtype)])
    return buf


def unpack(
    buf: jax.Array, bucket: Bucket, leaves: Sequence[Leaf]
) -> Dict[int, jax.Array]:
    out: Dict[int, jax.Array] = {}
    off = 0
    for i in bucket.leaf_ids:
        n = leaves[i].size
        out[i] = buf[off : off + n].reshape(leaves[i].shape)
        off += n
    return out
