"""Self-contained multi-device correctness checks for the collective layer.

Run as ``python -m repro.core.dist_checks`` — it forces 8 virtual CPU devices
(must happen before jax initialises, hence a dedicated process) and verifies
every strategy against ``lax.psum`` ground truth.  The pytest suite invokes
this module in a subprocess; the exit code + JSON on stdout carry results.
"""
import os
import sys

if __name__ == "__main__":  # set BEFORE importing jax
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import json  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def _mesh1d(w=8):
    return jax.make_mesh((w,), ("data",))


def _shard_map(f, mesh, in_specs, out_specs):
    return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _run_all_reduce(fn_name: str, w: int = 8, n: int = 1024 * 3):
    from repro.core import collectives as C

    mesh = _mesh1d(w)
    n = n + ((-n) % (w * 8))
    x = jax.random.normal(jax.random.PRNGKey(0), (w, n), jnp.float32)

    def body(xs):
        local = xs.reshape(-1)
        return C.ALL_REDUCE_FNS[fn_name](local, "data", w)[None]

    got = jax.jit(_shard_map(body, mesh, in_specs=(P("data", None),), out_specs=P("data", None)))(x)
    want = np.asarray(x).sum(0)
    for d in range(w):
        np.testing.assert_allclose(np.asarray(got[d]), want, rtol=2e-5, atol=2e-4)


def check_ring():
    _run_all_reduce("ring")


def check_ring_multicast():
    _run_all_reduce("ring+multicast")


def check_butterfly():
    _run_all_reduce("butterfly")


def check_rabenseifner():
    _run_all_reduce("rabenseifner")


def check_ps():
    _run_all_reduce("ps")


def check_reduce_scatter():
    from repro.core import collectives as C

    w, n = 8, 1024
    mesh = _mesh1d(w)
    x = jax.random.normal(jax.random.PRNGKey(1), (w, n), jnp.float32)

    def body(xs):
        return C.ring_reduce_scatter(xs.reshape(-1), "data", w)[None]

    got = jax.jit(_shard_map(body, mesh, (P("data", None),), P("data", None)))(x)
    want = np.asarray(x).sum(0).reshape(w, -1)
    for d in range(w):
        np.testing.assert_allclose(np.asarray(got[d]), want[d], rtol=2e-5, atol=2e-4)


def check_all_gather():
    from repro.core import collectives as C

    w, n = 8, 96
    mesh = _mesh1d(w)
    x = jax.random.normal(jax.random.PRNGKey(2), (w, n), jnp.float32)

    def body(xs):
        return C.ring_all_gather(xs.reshape(-1), "data", w)[None]

    got = jax.jit(_shard_map(body, mesh, (P("data", None),), P("data", None)))(x)
    want = np.asarray(x).reshape(-1)
    for d in range(w):
        np.testing.assert_allclose(np.asarray(got[d]), want, rtol=1e-6)


def check_hierarchical():
    from repro.core import collectives as C

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 256), jnp.float32)

    def body(xs):
        return C.hierarchical_all_reduce(xs.reshape(-1), "data", 4, "pod")[None, None]

    got = jax.jit(
        _shard_map(body, mesh, (P("pod", "data", None),), P("pod", "data", None))
    )(x)
    want = np.asarray(x).sum((0, 1))
    for p in range(2):
        for d in range(4):
            np.testing.assert_allclose(np.asarray(got[p, d]), want, rtol=2e-5, atol=2e-4)


def check_int8():
    from repro.core import compression as Z

    w, n = 8, 4096
    mesh = _mesh1d(w)
    x = jax.random.normal(jax.random.PRNGKey(4), (w, n), jnp.float32)

    def body(xs):
        return Z.int8_ring_all_reduce(xs.reshape(-1), "data", w)[None]

    got = jax.jit(_shard_map(body, mesh, (P("data", None),), P("data", None)))(x)
    want = np.asarray(x).sum(0)
    # int8 wire format: expect ~1% relative error on the sum of 8 gaussians
    err = np.abs(np.asarray(got[0]) - want)
    rel = err.max() / (np.abs(want).max())
    assert rel < 0.05, f"int8 all-reduce error too large: {rel}"


def check_topk():
    from repro.core import compression as Z

    w, n = 8, 4096
    mesh = _mesh1d(w)
    x = jax.random.normal(jax.random.PRNGKey(5), (w, n), jnp.float32)

    def body(xs):
        local = xs.reshape(-1)
        res = jnp.zeros_like(local)
        red, new_res = Z.topk_ef_all_reduce(local, res, "data", w, k_fraction=1.0)
        return red[None], new_res[None]

    red, res = jax.jit(_shard_map(body, mesh, (P("data", None),), (P("data", None),) * 2))(x)
    want = np.asarray(x).sum(0)
    # k=100% must be exact and leave zero residual
    np.testing.assert_allclose(np.asarray(red[0]), want, rtol=1e-5, atol=1e-5)
    assert np.abs(np.asarray(res)).max() < 1e-7


def check_gradsync_tree():
    """End-to-end GradSync on a realistic mixed-dtype pytree, all strategies."""
    from repro.core.api import GradSync, GradSyncConfig

    w = 8
    mesh = _mesh1d(w)
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    tree_ex = {
        "wq": jnp.zeros((64, 33), jnp.bfloat16),
        "scale": jnp.zeros((7,), jnp.float32),
        "moe": {"wi": jnp.zeros((4, 16, 8), jnp.bfloat16)},
    }
    trees = jax.tree.map(
        lambda x: jax.random.normal(ks[0], (w,) + x.shape, jnp.float32).astype(x.dtype),
        tree_ex,
    )

    for strategy in ["psum", "ring", "ring+multicast", "butterfly", "rabenseifner", "ps"]:
        sync = GradSync(
            GradSyncConfig(strategy=strategy, average=False, bucket_bytes=4096), tree_ex
        )

        def body(tr):
            local = jax.tree.map(lambda x: x[0], tr)
            out, _ = sync(local, {"data": w})
            return jax.tree.map(lambda x: x[None], out)

        got = jax.jit(
            _shard_map(
                body,
                mesh,
                (jax.tree.map(lambda _: P("data"), tree_ex),),
                jax.tree.map(lambda _: P("data"), tree_ex),
            )
        )(trees)
        want = jax.tree.map(lambda x: np.asarray(x, np.float32).sum(0), trees)
        for gk, wk in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            for d in range(w):
                np.testing.assert_allclose(
                    np.asarray(gk[d], np.float32), wk, rtol=2e-2, atol=2e-2,
                    err_msg=strategy,
                )


def check_explicit_strategies_match_gspmd():
    """Full train steps: every paper strategy must produce the same params as
    the XLA-native (gspmd/psum) path on an 8-way DP mesh."""
    from repro.optim import OptConfig
    from repro.train import TrainConfig, Trainer

    def run(strategy):
        tcfg = TrainConfig(
            arch="qwen1.5-0.5b", smoke=True, steps=2, log_every=0,
            strategy=strategy, batch_override=8, seq_override=32,
            opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        )
        tr = Trainer(tcfg)
        tr.init_or_restore()
        tr.run()
        return jax.tree.map(lambda x: np.asarray(x, np.float32), tr.params)

    ref = run("gspmd")
    for strategy in ("psum", "ring", "butterfly", "rabenseifner", "ps"):
        got = run(strategy)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-3,
                                       err_msg=strategy)


def check_hierarchical_train_step():
    """Explicit hierarchical sync on a (pod=2, data=4) mesh trains finitely."""
    from repro.optim import OptConfig
    from repro.train import TrainConfig, Trainer

    # 3-tuple mesh maps to ("pod", "data", "model"); model axis size 1
    tcfg = TrainConfig(
        arch="qwen1.5-0.5b", smoke=True, steps=2, log_every=0,
        strategy="hierarchical", mesh_shape=(2, 4, 1),
        batch_override=8, seq_override=32,
        opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10),
    )
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    assert np.isfinite(res["last_loss"])


CHECKS = [
    check_ring,
    check_ring_multicast,
    check_butterfly,
    check_rabenseifner,
    check_ps,
    check_reduce_scatter,
    check_all_gather,
    check_hierarchical,
    check_int8,
    check_topk,
    check_gradsync_tree,
    check_explicit_strategies_match_gspmd,
    check_hierarchical_train_step,
]


def main() -> int:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    results = {}
    failed = 0
    for fn in CHECKS:
        if only and fn.__name__ != only:
            continue
        try:
            fn()
            results[fn.__name__] = "ok"
        except Exception:
            results[fn.__name__] = traceback.format_exc()
            failed += 1
    print(json.dumps(results))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
