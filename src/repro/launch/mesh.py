"""Production mesh construction (dry-run target: v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run driver sets ``XLA_FLAGS`` before the first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips across 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dp_mesh(workers: int, pods: int = 1):
    """Pure data-parallel mesh for the explicit paper-strategy runtime."""
    if pods > 1:
        return jax.make_mesh((pods, workers), ("pod", "data"))
    return jax.make_mesh((workers,), ("data",))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
