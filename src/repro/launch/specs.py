"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

``input_specs`` returns abstract inputs only — no device allocation — in the
exact structure the corresponding step function consumes:

  * train cells   -> (params, opt_state, batch) for ``train_step``
  * prefill cells -> (params, batch) for ``prefill_step``
  * decode cells  -> (params, token, cache) for ``serve_step``

Modality frontends are stubbed here per the assignment: seamless gets
precomputed (B, S, d_model) frame embeddings; chameleon's VQ image tokens are
ordinary ids inside its unified vocab.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.optim import init_opt_state

PyTree = Any

# per-arch microbatch accumulation for train_4k (activation-memory budget)
TRAIN_GRAD_ACCUM = {
    "qwen1.5-0.5b": 1,
    "starcoder2-3b": 2,
    "gemma2-2b": 2,
    "llama3-405b": 8,   # microbatch 32: divisible on both 16x16 and 2x16x16
    "seamless-m4t-large-v2": 2,
    "falcon-mamba-7b": 8,
    "moonshot-v1-16b-a3b": 4,
    "mixtral-8x7b": 8,
    "chameleon-34b": 8,
    "jamba-v0.1-52b": 8,
}


def grad_accum_for(arch: str, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    return TRAIN_GRAD_ACCUM.get(arch, 1)


def params_shape(cfg: ModelConfig, seed: int = 0) -> PyTree:
    return jax.eval_shape(lambda k: M.init_params(k, cfg), jax.random.PRNGKey(seed))


def opt_state_shape(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(init_opt_state, params_shape(cfg))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, grad_accum: int = 1) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if grad_accum > 1:
        assert B % grad_accum == 0, (B, grad_accum)
        mb = B // grad_accum
        b = {
            "tokens": jax.ShapeDtypeStruct((grad_accum, mb, S), tok),
            "labels": jax.ShapeDtypeStruct((grad_accum, mb, S), tok),
        }
        if cfg.is_encoder_decoder:
            b["frames"] = jax.ShapeDtypeStruct(
                (grad_accum, mb, S, cfg.d_model), jnp.bfloat16
            )
        return b
    b = {
        "tokens": jax.ShapeDtypeStruct((B, S), tok),
        "labels": jax.ShapeDtypeStruct((B, S), tok),
    }
    if cfg.is_encoder_decoder:
        b["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    return b


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    b = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    return b


def cache_shape(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    B, S = shape.global_batch, shape.seq_len
    return jax.eval_shape(lambda: M.init_cache(cfg, B, S))


def decode_token_spec(shape: ShapeConfig):
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def input_specs(arch: str, shape_name: str) -> Tuple[str, Tuple]:
    """Returns (kind, specs-tuple) for the cell's step function."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape.kind == "train":
        ga = grad_accum_for(arch, shape)
        return "train", (
            params_shape(cfg),
            opt_state_shape(cfg),
            batch_specs(cfg, shape, ga),
        )
    if shape.kind == "prefill":
        return "prefill", (params_shape(cfg), prefill_specs(cfg, shape))
    return "decode", (
        params_shape(cfg),
        decode_token_spec(shape),
        cache_shape(cfg, shape),
    )
