"""Serving launcher: batched decode over the slot-based engine.

Example (CPU smoke):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
        --requests 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="", help="restore params from here")
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.serving import Request, ServeConfig, ServingEngine
    from repro.train import checkpoint as ckpt

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        step = ckpt.latest_step(args.ckpt_dir + "/params")
        if step is not None:
            params = ckpt.restore_checkpoint(args.ckpt_dir + "/params", step, params)
            print(f"restored params at step {step}")

    eng = ServingEngine(cfg, params, ServeConfig(
        slots=args.slots, max_len=args.max_len, temperature=args.temperature))
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=list(rng.integers(1, min(cfg.vocab_size, 1000),
                                         size=rng.integers(4, 12))),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")
    print("sample output:", reqs[0].out[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
