"""Training launcher.

Examples:
    # CPU smoke run (reduced arch, tiny shapes)
    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke --steps 20

    # explicit paper-strategy gradient sync on an 8-way DP mesh (fake devices)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --strategy ring --mesh 8

    # production shapes (real pod; this process would be one host of the pod)
    python -m repro.launch.train --arch llama3-405b --shape train_4k --mesh 16,16

On a real multi-host pod this process calls ``jax.distributed.initialize()``
(env-driven) before building the mesh; single-process here.
"""
from __future__ import annotations

import argparse
import os


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mesh", default="", help="comma mesh shape, e.g. 16,16")
    ap.add_argument("--strategy", default="gspmd",
                    help="gspmd|ring|ring+multicast|butterfly|rabenseifner|ps|hierarchical|psum")
    ap.add_argument("--compression", default="", help="''|int8|topk")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: jax.distributed.initialize() from env")
    args = ap.parse_args()

    import jax

    if args.distributed:
        jax.distributed.initialize()

    from repro.optim import OptConfig
    from repro.train import TrainConfig, Trainer

    mesh_shape = tuple(int(x) for x in args.mesh.split(",")) if args.mesh else ()
    tcfg = TrainConfig(
        arch=args.arch,
        shape=args.shape,
        smoke=args.smoke,
        steps=args.steps,
        mesh_shape=mesh_shape,
        strategy=args.strategy,
        compression=args.compression,
        grad_accum=args.grad_accum,
        batch_override=args.batch,
        seq_override=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        log_every=args.log_every,
        opt=OptConfig(lr=args.lr, warmup_steps=args.warmup,
                      total_steps=max(args.steps, 1000)),
    )
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    print(f"done: {res}")
    if tcfg.ckpt_dir:
        tr.save()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
