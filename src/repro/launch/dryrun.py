import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): AOT lower + compile every
(architecture x input-shape) cell on the production meshes, and extract the
roofline terms (deliverable g) from the compiled artifact.

Usage:
    python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun --all [--multi-pod] [--jobs 4]

Every invocation writes/updates ``dryrun_results/<mesh>/<arch>__<shape>.json``
with: memory_analysis, cost_analysis, per-collective wire bytes, the three
roofline terms, and compile time.  ``--all`` drives each cell in a fresh
subprocess (isolation + parallelism); completed cells are skipped unless
``--force``.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "dryrun_results")


def _cell_path(arch: str, shape: str, multi_pod: bool) -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    return os.path.join(RESULTS_DIR, mesh, f"{arch}__{shape}.json")


def run_cell(arch: str, shape_name: str, multi_pod: bool, perf: str = "") -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import cell_runnable, get_config, get_shape
    from repro.launch import specs as S
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shardings import ShardingPlan
    from repro.models import model as M
    from repro.optim import OptConfig
    from repro.roofline import model_flops, roofline_terms
    from repro.roofline.analytic import cell_flops_per_chip, cell_hbm_bytes_per_chip
    from repro.roofline.hlo_parse import collective_bytes_trip_aware
    from repro.train import steps as steps_lib

    cell = cell_runnable(arch, shape_name)
    if not cell.runnable:
        return {"arch": arch, "shape": shape_name, "skipped": cell.skip_reason}

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    plan = ShardingPlan(cfg, mesh)
    kind, cell_specs = S.input_specs(arch, shape_name)

    perf_list = [f for f in perf.split(",") if f]
    if perf_list:
        from repro.models.perf import set_flags
        kv = {}
        for f in perf_list:
            if "=" in f:
                k, v = f.split("=", 1)
                kv[k] = v
            else:
                kv[f] = True
        set_flags(mesh=mesh, batch_axes=plan.batch_axes,
                  **{k: v for k, v in kv.items() if k != "grad_zero1"})
        if "grad_zero1" in kv:
            set_flags(grad_zero1=True)
    t0 = time.time()

    if kind == "train":
        ga = S.grad_accum_for(arch, shape)
        params_sh = plan.param_shardings(cell_specs[0])
        opt_sh = plan.shardings_for({
            "step": P(),
            "m": plan.param_specs(cell_specs[0], zero1=True),
            "v": plan.param_specs(cell_specs[0], zero1=True),
            "master": plan.param_specs(cell_specs[0], zero1=True),
        })
        axes = plan.batch_axes
        bspec = (lambda x: P(None, axes, *([None] * (x.ndim - 2)))) if ga > 1 \
            else (lambda x: P(axes, *([None] * (x.ndim - 1))))
        batch_sh = plan.shardings_for(jax.tree.map(bspec, cell_specs[2]))
        grad_sh = None
        if "grad_zero1" in perf_list:
            grad_sh = plan.shardings_for(
                plan.param_specs(cell_specs[0], zero1=True)
            )
        step = steps_lib.make_train_step(cfg, OptConfig(), grad_accum=ga,
                                         grad_shardings=grad_sh)
        fn = jax.jit(
            step,
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
        )
        tokens = shape.global_batch * shape.seq_len
    elif kind == "prefill":
        params_sh = plan.param_shardings(cell_specs[0])
        axes = plan.batch_axes
        batch_sh = plan.shardings_for(
            jax.tree.map(lambda x: P(axes, *([None] * (x.ndim - 1))), cell_specs[1])
        )
        fn = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, max_len=shape.seq_len),
            in_shardings=(params_sh, batch_sh),
        )
        tokens = shape.global_batch * shape.seq_len
    else:  # decode
        params_sh = plan.param_shardings(cell_specs[0])
        cache_sh = plan.shardings_for(plan.cache_specs(cell_specs[2]))
        tok_axes = plan.batch_axes
        total_b = 1
        for a in tok_axes:
            total_b *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
        tok_spec = P(tok_axes, None) if shape.global_batch % total_b == 0 else P()
        fn = jax.jit(
            lambda p, t, c: M.decode_step(p, t, c, cfg),
            in_shardings=(params_sh, plan.shardings_for(tok_spec), cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        )
        tokens = shape.global_batch  # one token per sequence per step

    lowered = fn.lower(*cell_specs)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_d[k] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if k in ("flops", "bytes accessed", "transcendentals",
                       "bytes accessed0{}", "bytes accessed1{}", "utilization")}

    hlo = compiled.as_text()
    coll = collective_bytes_trip_aware(
        hlo, chips, pod_group_size=2 if multi_pod else None
    )
    n_params = cfg.param_count()
    ga = S.grad_accum_for(arch, shape)
    fl = cell_flops_per_chip(cfg, shape, chips)
    hb = cell_hbm_bytes_per_chip(cfg, shape, chips, grad_accum=ga)
    n_active = int(fl["active_params"])
    # analytic compute/memory terms (XLA cost_analysis undercounts scan
    # bodies — raw numbers retained below for reference)
    analytic_cost = {"flops": fl["per_chip"], "bytes accessed": hb["per_chip"]}
    mfl = model_flops(n_params, tokens, "train" if kind == "train" else "serve",
                      n_active)
    terms = roofline_terms(analytic_cost, coll, chips, mfl)

    return {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "perf": perf_list,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "params": int(n_params),
        "active_params": int(n_active),
        "tokens_per_step": int(tokens),
        "grad_accum": int(ga),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_d,
        "cost_analysis_raw": cost_d,
        "analytic_flops_per_chip": fl["per_chip"],
        "analytic_hbm_bytes_per_chip": hb["per_chip"],
        "collective_bytes": {k: float(v) for k, v in coll.items()},
        "roofline": terms,
    }


def _run_subprocess(arch: str, shape: str, multi_pod: bool) -> subprocess.Popen:
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # the child sets its own
    return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


def run_all(multi_pod: bool, jobs: int, force: bool) -> int:
    from repro.configs import all_cells

    cells = [c for c in all_cells()]
    todo = []
    for c in cells:
        path = _cell_path(c.arch, c.shape, multi_pod)
        if not force and os.path.exists(path):
            continue
        todo.append(c)
    print(f"dry-run: {len(todo)} cells to run ({len(cells) - len(todo)} cached)")
    running: list = []
    failed = []
    while todo or running:
        while todo and len(running) < jobs:
            c = todo.pop(0)
            print(f"  launch {c.arch} x {c.shape}")
            running.append((c, _run_subprocess(c.arch, c.shape, multi_pod)))
        for (c, p) in list(running):
            if p.poll() is None:
                continue
            running.remove((c, p))
            out = p.stdout.read()
            if p.returncode != 0:
                failed.append((c, out[-2000:]))
                print(f"  FAIL {c.arch} x {c.shape}\n{out[-2000:]}")
            else:
                print(f"  done {c.arch} x {c.shape}")
        time.sleep(0.5)
    print(f"dry-run complete: {len(failed)} failures")
    return 1 if failed else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--perf", default="",
                    help="comma list: loss_sharding,bf16_grad_accum,"
                         "norm_bf16_bwd,grad_zero1,moe_ep")
    args = ap.parse_args()

    if args.all:
        return run_all(args.multi_pod, args.jobs, args.force)

    path = _cell_path(args.arch, args.shape, args.multi_pod)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, args.perf)
    except Exception:
        res = {
            "arch": args.arch, "shape": args.shape, "error": traceback.format_exc()
        }
        with open(path + ".err", "w") as f:
            json.dump(res, f, indent=2)
        print(json.dumps({"error": res["error"][-1500:]}, indent=2))
        return 1
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    summary = {k: res.get(k) for k in
               ("arch", "shape", "kind", "mesh", "skipped", "compile_s")}
    if "roofline" in res:
        summary["bottleneck"] = res["roofline"]["bottleneck"]
        summary["terms_ms"] = {
            k: round(res["roofline"][k] * 1e3, 3)
            for k in ("compute_s", "memory_s", "collective_s")
        }
        summary["mfu_at_bound"] = round(res["roofline"]["mfu_at_bound"], 4)
    print(json.dumps(summary, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
