"""Launchers."""
