"""Sharding plans: PartitionSpecs for params / optimizer state / batches / caches.

Rules (DESIGN.md §5):
  * TP over the ``model`` axis: attention heads, FFN hidden, experts, vocab.
  * FSDP over ``data`` for large archs (and always for optimizer state —
    that is zero-1).
  * multi-pod: the ``pod`` axis composes with ``data`` for the batch; weights
    are replicated across pods (gradient sync crosses pods — hierarchical).
  * every rule checks divisibility and degrades to the next-best axis
    (e.g. kv-heads < model-axis => shard head_dim instead; odd vocab =>
    replicate) so all 10 archs produce valid specs on any mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any

FSDP_PARAM_THRESHOLD = 8e9        # params; above this, shard weights over data


def _axis(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingPlan:
    """Derives all PartitionSpecs for one (config, mesh) pair."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, fsdp: Optional[bool] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.model_size = _axis(mesh, "model")
        self.data_size = _axis(mesh, "data")
        self.pod_size = _axis(mesh, "pod")
        self.batch_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if _axis(mesh, a) > 1
        ) or ("data",)
        if fsdp is None:
            fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
        self.fsdp = fsdp

    # ------------------------------------------------------------- helpers
    def _m(self, dim: int) -> Optional[str]:
        """'model' if the axis exists and dim divides, else None."""
        if "model" not in self.mesh.axis_names:
            return None
        return "model" if _div(dim, self.model_size) else None

    def _f(self, dim: int, force: bool = False) -> Optional[str]:
        """'data' (fsdp) if enabled+divisible."""
        if "data" not in self.mesh.axis_names:
            return None
        if (self.fsdp or force) and _div(dim, self.data_size):
            return "data"
        return None

    # ------------------------------------------------------- per-leaf rule
    def _leaf_spec(self, path: str, shape: Tuple[int, ...], zero1: bool) -> P:
        cfg = self.cfg
        s = list(shape)
        stacked = path.startswith("['blocks']") or path.startswith("['enc_blocks']")
        if stacked:
            s = s[1:]                     # drop the layer-group stack dim

        def out(*spec):
            spec = list(spec) + [None] * (len(s) - len(spec))
            if stacked:
                spec = [None] + spec
            return P(*spec)

        f = (lambda d: self._f(d, force=zero1))
        m = self._m

        if "embed" in path or "lm_head" in path:            # (V, d)
            return out(m(s[0]), f(s[1]))
        if len(s) == 1:                                      # norms, biases, D
            if zero1:
                return out(f(s[0]) or m(s[0]))
            return out(None)
        if "'wq'" in path:                                   # (d, H, hd)
            mh = m(s[1])
            return out(f(s[0]), mh, None if mh else m(s[2]))
        if "'wk'" in path or "'wv'" in path:                 # (d, Hk, hd)
            mk = m(s[1])
            return out(f(s[0]), mk, None if mk else m(s[2]))
        if "'wo'" in path and len(s) == 3:                   # (H, hd, d)
            mh = m(s[0])
            return out(mh, None if mh else m(s[1]), f(s[2]))
        if "'bq'" in path or "'bk'" in path or "'bv'" in path:
            return out(None, None)
        if "moe" in path and len(s) == 3:                    # (E, d, f) / (E, f, d)
            me = m(s[0])
            if "'wi'" in path or "'wg'" in path:
                return out(me, f(s[1]), None if me else m(s[2]))
            return out(me, None if me else m(s[1]), f(s[2]))
        if "router" in path:                                 # (d, E)
            return out(f(s[0]), None)
        if "shared_wi" in path or "shared_wg" in path:       # (d, fs)
            return out(f(s[0]), m(s[1]))
        if "shared_wo" in path:                              # (fs, d)
            return out(m(s[0]), f(s[1]))
        if "'in_proj'" in path:                              # (d, 2*di)
            return out(f(s[0]), m(s[1]))
        if "'conv_w'" in path:                               # (W, di)
            return out(None, m(s[1]))
        if "'x_proj'" in path:                               # (di, R+2N)
            return out(m(s[0]), None)
        if "'dt_proj'" in path:                              # (R, di)
            return out(None, m(s[1]))
        if "'A_log'" in path:                                # (di, N)
            return out(m(s[0]), None)
        if "'out_proj'" in path:                             # (di, d)
            return out(m(s[0]), f(s[1]))
        if "'wi'" in path or "'wg'" in path:                 # mlp (d, f)
            return out(f(s[0]), m(s[1]))
        if "'wo'" in path:                                   # mlp (f, d)
            return out(m(s[0]), f(s[1]))
        return out(*([None] * len(s)))

    # --------------------------------------------------------------- trees
    def param_specs(self, params_shape: PyTree, zero1: bool = False) -> PyTree:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
        specs = [
            self._leaf_spec(jax.tree_util.keystr(path), tuple(x.shape), zero1)
            for path, x in flat
        ]
        return jax.tree_util.tree_unflatten(treedef, specs)

    def param_shardings(self, params_shape: PyTree, zero1: bool = False) -> PyTree:
        return jax.tree.map(
            lambda spec: NamedSharding(self.mesh, spec),
            self.param_specs(params_shape, zero1),
            is_leaf=lambda x: isinstance(x, P),
        )

    # batches: tokens (B, S) etc.
    def batch_spec(self) -> P:
        return P(self.batch_axes)

    def batch_specs(self, batch_shape: PyTree) -> PyTree:
        b = self.batch_axes

        def spec(x):
            if _div(x.shape[0], int(np.prod([_axis(self.mesh, a) for a in b]))):
                return P(b, *([None] * (len(x.shape) - 1)))
            return P(*([None] * len(x.shape)))

        return jax.tree.map(spec, batch_shape)

    # decode caches: {"k"/"v": (B, S, Hk, hd)} + mamba states + pos
    def cache_specs(self, cache_shape: PyTree) -> PyTree:
        bsz_axes = self.batch_axes
        total_b = int(np.prod([_axis(self.mesh, a) for a in bsz_axes]))

        def spec(path, x):
            p = jax.tree_util.keystr(path)
            s = x.shape
            if "pos" in p:
                return P()
            stacked = "'layers'" in p or "memory_kv" in p
            core = list(s[1:]) if stacked else list(s)
            out = [None] * len(core)
            # batch dim
            if _div(core[0], total_b):
                out[0] = bsz_axes
            elif core[0] == 1 and len(core) >= 2 and _div(core[1], self.data_size):
                # long-context single-request: shard the sequence dim
                out[1] = "data"
            if "'k'" in p or "'v'" in p:
                # kv heads / head_dim over model
                if len(core) == 4:
                    if _div(core[2], self.model_size):
                        out[2] = "model"
                    elif _div(core[3], self.model_size):
                        out[3] = "model"
            elif "'conv'" in p:                    # (B, W-1, di)
                if _div(core[2], self.model_size):
                    out[2] = "model"
            elif "'ssm'" in p:                     # (B, di, N)
                if _div(core[1], self.model_size):
                    out[1] = "model"
            if stacked:
                out = [None] + out
            return P(*out)

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
        return jax.tree_util.tree_unflatten(
            treedef, [spec(p, x) for p, x in flat]
        )

    def shardings_for(self, specs: PyTree) -> PyTree:
        return jax.tree.map(
            lambda spec: NamedSharding(self.mesh, spec),
            specs,
            is_leaf=lambda x: isinstance(x, P),
        )
