"""Batched serving engine: slot-based continuous batching over fixed shapes.

XLA wants static shapes, so the engine maintains ``slots`` concurrent decode
lanes over a shared (B, max_len) KV cache.  Requests are admitted into free
slots; each engine step decodes one token for every active slot; finished
slots are recycled without stopping the batch (continuous batching at the
step granularity — the vLLM idea restricted to static shapes).

Single-slot-length limitation: all slots share one ``pos`` counter (the
model-level cache is position-synchronised), so the engine runs *waves*:
requests admitted into a wave start together at the wave's base position with
left-padding.  This keeps the step function identical to the dry-run
``serve_step`` the roofline measures.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    slots: int = 8                     # concurrent sequences (batch)
    max_len: int = 512
    temperature: float = 0.0           # 0 => greedy
    seed: int = 0
    eos_id: int = -1                   # -1 => run to max_new


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: PyTree, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self._prefill = jax.jit(
            lambda p, b: M.prefill(p, b, cfg, max_len=scfg.max_len)
        )
        self._step = jax.jit(lambda p, t, c: M.decode_step(p, t, c, cfg))
        self._rng = np.random.default_rng(scfg.seed)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.scfg.temperature <= 0:
            return logits.argmax(-1)
        z = logits / self.scfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(r), p=r) for r in p])

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests in slot-waves; returns the same list, filled."""
        scfg = self.scfg
        pending = list(requests)
        while pending:
            wave = pending[: scfg.slots]
            pending = pending[len(wave):]
            self._run_wave(wave)
        return requests

    def _run_wave(self, wave: List[Request]) -> None:
        scfg, cfg = self.scfg, self.cfg
        B = scfg.slots
        t0 = time.perf_counter()
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt       # left pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros((B, plen, cfg.d_model), jnp.bfloat16)
        logits, cache = self._prefill(self.params, batch)
        logits = np.asarray(logits, np.float32)

        max_new = max(r.max_new for r in wave)
        active = np.array([not r.done for r in wave] + [False] * (B - len(wave)))
        for step_i in range(max_new):
            nxt = self._sample(logits)
            for i, r in enumerate(wave):
                if active[i] and len(r.out) < r.max_new:
                    r.out.append(int(nxt[i]))
                    if int(nxt[i]) == scfg.eos_id or len(r.out) >= r.max_new:
                        active[i] = False
                        r.done = True
            if not active.any():
                break
            logits_j, cache = self._step(
                self.params, jnp.asarray(nxt[:, None].astype(np.int32)), cache
            )
            logits = np.asarray(logits_j, np.float32)
        dt = time.perf_counter() - t0
        for r in wave:
            r.done = True
            r.latency_s = dt
