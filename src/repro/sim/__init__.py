"""Trace-driven simulator of distributed training communication (§5-§9)."""
from repro.sim.events import Sim
from repro.sim.strategies import (
    MECHANISMS,
    SimResult,
    simulate,
    simulate_butterfly,
    simulate_ps,
    simulate_ring,
    speedup_table,
)
from repro.sim.traces import (
    INCEPTION_V3,
    PAPER_CNNS,
    RESNET_101,
    RESNET_200,
    VGG16,
    LayerTrace,
    ModelTrace,
    toy_3op,
    trace_from_cost_analysis,
)

__all__ = [
    "Sim", "MECHANISMS", "SimResult", "simulate", "simulate_butterfly",
    "simulate_ps", "simulate_ring", "speedup_table", "INCEPTION_V3",
    "PAPER_CNNS", "RESNET_101", "RESNET_200", "VGG16", "LayerTrace",
    "ModelTrace", "toy_3op", "trace_from_cost_analysis",
]
