"""Task-graph builders for each mechanism the paper studies (§3, §8, §9).

Every ``simulate_*`` function unrolls ``iterations`` training iterations of
the given trace into the event engine and returns per-iteration markers, from
which ``iteration_time`` computes the steady-state time the paper reports.

Mechanisms:
  * parameter server (baseline), +multicast, +in-network aggregation, +both
    — with round-robin vs block distribution (§9.4), round-robin vs
    size-balanced vs split parameter assignment (§9.1), optional global
    barrier removal (§9.3);
  * ring-reduce, with/without parameter messaging (§9.2) and with multicast
    second ring (§8.4);
  * butterfly mixing.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.sim import events as E
from repro.sim.traces import ModelTrace


@dataclasses.dataclass
class SimResult:
    markers: List[float]               # per-iteration completion milestones
    makespan: float
    sim: E.Sim
    meta: Dict

    @property
    def iteration_time(self) -> float:
        """Steady-state iteration time: mean gap between iteration markers
        (the paper's (iter3 - iter1)/2 measurement generalised)."""
        m = self.markers
        if len(m) == 1:
            return m[0]
        return (m[-1] - m[0]) / (len(m) - 1)


# ---------------------------------------------------------------------------
# shared compute pipeline: forward pass + backprop chains for one worker
# ---------------------------------------------------------------------------
def _fwd_chain(sim, trace, w, it, recv_dep, extra_deps=()):
    """Forward layers; layer l waits for its params (recv_dep(l)) + fwd l-1."""
    n = len(trace.layers)
    scale = trace.worker_scale(w)
    prev = None
    for l in range(n):
        deps = list(extra_deps)
        r = recv_dep(l)
        if r is not None:
            deps.append(r)
        if prev is not None:
            deps.append(prev)
        prev = sim.add(
            ("fwd", it, w, l),
            deps=deps,
            resources=(E.gpu(w),),
            duration=trace.layers[l].fwd_time * scale,
        )
    return prev                         # fwd complete


def _bp_chain(sim, trace, w, it, start_dep):
    """Backprop layers n-1..0; returns dict layer->grad-ready task."""
    n = len(trace.layers)
    scale = trace.worker_scale(w)
    grads = {}
    prev = start_dep
    for l in range(n - 1, -1, -1):
        dur = trace.layers[l].bp_time
        if l == n - 1:
            dur += trace.bp_first_extra
        grads[l] = sim.add(
            ("bp", it, w, l),
            deps=[prev] if prev is not None else [],
            resources=(E.gpu(w),),
            duration=dur * scale,
        )
        prev = grads[l]
    return grads


# ---------------------------------------------------------------------------
# parameter-server family
# ---------------------------------------------------------------------------
def _assign_to_ps(trace: ModelTrace, num_ps: int, policy: str):
    """Return list of (layer, ps, bits) 'slices'."""
    sizes = [l.size_bits for l in trace.layers]
    slices = []
    if policy == "split":
        for i, s in enumerate(sizes):
            for p in range(num_ps):
                slices.append((i, p, s / num_ps))
        return slices
    if policy == "round_robin":
        owners = [i % num_ps for i in range(len(sizes))]
    elif policy == "size_balanced":
        load = [0.0] * num_ps
        owners = [0] * len(sizes)
        for i in sorted(range(len(sizes)), key=lambda i: -sizes[i]):
            p = min(range(num_ps), key=lambda q: load[q])
            owners[i] = p
            load[p] += sizes[i]
    else:
        raise ValueError(policy)
    return [(i, owners[i], sizes[i]) for i in range(len(sizes))]


def simulate_ps(
    trace: ModelTrace,
    workers: int = 32,
    bandwidth: float = 25e9,
    num_ps: int = 1,
    multicast: bool = False,
    in_network_agg: bool = False,
    iterations: int = 3,
    barrier: bool = True,
    distribution: str = "round_robin",   # or "block" (§9.4)
    assignment: str = "round_robin",     # or "size_balanced" / "split" (§9.1)
    half_duplex_ps: bool = False,        # PS NIC shared between rx/tx
) -> SimResult:
    """Parameter-server mechanism family.

    ``half_duplex_ps`` models a PS whose NIC (or RPC stack) cannot overlap
    distribution sends with aggregation receives — this matches the paper's
    TF1.4-era measurements, where iteration time is essentially
    dist + agg serialised; the default full-duplex model lets iteration k's
    aggregation overlap the staggered tail of its own distribution, which is
    how a modern transport behaves.  Both are reported in EXPERIMENTS.md.
    """
    sim = E.Sim()
    W, bw = workers, bandwidth
    n = len(trace.layers)

    def ps_egress(p):
        return E.egress(E.ps(p))

    def ps_ingress(p):
        return ps_egress(p) if half_duplex_ps else E.ingress(E.ps(p))
    slices = _assign_to_ps(trace, num_ps, assignment)
    by_layer: Dict[int, List[Tuple[int, float]]] = {}
    for i, p, bits in slices:
        by_layer.setdefault(i, []).append((p, bits))
    by_ps: Dict[int, List[Tuple[int, float]]] = {}
    for i, p, bits in slices:
        by_ps.setdefault(p, []).append((i, bits))

    prev_barrier = None                 # distribution gate, full-barrier mode
    prev_agg: Dict[int, object] = {}    # per-layer gate, no-barrier mode
    markers = []

    for it in range(iterations):
        # ---------------- distribution phase --------------------------------
        # ordering on each PS egress = insertion order (FIFO at equal ready)
        recv: Dict[Tuple[int, int], List] = {}

        def dist_deps(layer):
            if barrier:
                return [prev_barrier] if prev_barrier is not None else []
            d = prev_agg.get(layer)
            return [d] if d is not None else []

        for p in range(num_ps):
            mine = by_ps.get(p, [])
            if distribution == "round_robin":
                order = [(i, bits, w) for (i, bits) in mine for w in range(W)]
            elif distribution == "block":
                order = [(i, bits, w) for w in range(W) for (i, bits) in mine]
            else:
                raise ValueError(distribution)
            if multicast:
                for (i, bits) in mine:
                    t = sim.add(
                        ("dist", it, p, i, "mc"),
                        deps=dist_deps(i),
                        resources=(ps_egress(p),)
                        + tuple(E.ingress(E.worker(w)) for w in range(W)),
                        duration=bits / bw,
                    )
                    for w in range(W):
                        recv.setdefault((w, i), []).append(t)
            else:
                for (i, bits, w) in order:
                    t = sim.add(
                        ("dist", it, p, i, w),
                        deps=dist_deps(i),
                        resources=(ps_egress(p), E.ingress(E.worker(w))),
                        duration=bits / bw,
                    )
                    recv.setdefault((w, i), []).append(t)

        # ---------------- forward + backprop --------------------------------
        agg_done: Dict[int, List] = {}
        for w in range(W):
            def recv_dep(l, w=w):
                deps = recv[(w, l)]
                if len(deps) == 1:
                    return deps[0]
                return sim.add((("recvall", it, w, l)), deps=deps)

            fwd_done = _fwd_chain(sim, trace, w, it, recv_dep)
            grads = _bp_chain(sim, trace, w, it, fwd_done)

            # ------------- aggregation sends (pipelined with bp) -------------
            for l in range(n - 1, -1, -1):
                for (p, bits) in by_layer[l]:
                    if in_network_agg:
                        # worker -> switch leg: occupies worker egress only
                        t = sim.add(
                            ("upsend", it, w, l, p),
                            deps=[grads[l]],
                            resources=(E.egress(E.worker(w)),),
                            duration=bits / bw,
                        )
                        agg_done.setdefault((l, p), []).append(t)
                    else:
                        t = sim.add(
                            ("up", it, w, l, p),
                            deps=[grads[l]],
                            resources=(E.egress(E.worker(w)), ps_ingress(p)),
                            duration=bits / bw,
                        )
                        agg_done.setdefault((l, p), []).append(t)

        # in-network agg: single cut-through aggregated arrival per (l, p)
        layer_agg: Dict[int, List] = {}
        for (l, p), sends in sorted(agg_done.items(), key=lambda kv: -kv[0][0]):
            if in_network_agg:
                bits = dict(by_layer[l])[p]
                t = sim.add(
                    ("agg", it, l, p),
                    deps=sends,
                    resources=(ps_ingress(p),),
                    duration=bits / bw,
                    ready_offset=-bits / bw,   # switch forwards cut-through
                )
                layer_agg.setdefault(l, []).append(t)
            else:
                layer_agg.setdefault(l, []).extend(sends)

        # per-layer aggregation-complete gates
        for l in range(n):
            prev_agg[l] = sim.add(("aggdone", it, l), deps=layer_agg[l])

        prev_barrier = sim.add(("barrier", it), deps=list(prev_agg.values()))
        markers.append(("barrier", it) if barrier else ("aggdone", it, 0))

    makespan = sim.run()
    marks = [sim.t(m) for m in markers]
    return SimResult(marks, makespan, sim, dict(mechanism="ps", W=W, bw=bw))


# ---------------------------------------------------------------------------
# ring-reduce
# ---------------------------------------------------------------------------
def _ring_chunks(trace: ModelTrace, W: int, messaging: bool):
    """Partition gradients into ring chunks.

    Returns list of (bits, ready_layer) where ready_layer is the layer whose
    backprop completion makes the chunk sendable.  Chunks are formed over the
    BACKPROP-ordered byte stream so readiness is monotone (§8.2.1).
    """
    n = len(trace.layers)
    order = list(range(n - 1, -1, -1))          # backprop order
    if not messaging:
        return [(trace.layers[l].size_bits, l) for l in order]
    total = trace.total_bits
    # byte intervals of each layer along the backprop-ordered stream
    spans = []
    cum = 0.0
    for l in order:
        s = trace.layers[l].size_bits
        spans.append((cum, cum + s, l))
        cum += s
    chunks = []
    for c in range(W):
        lo = total * c / W
        hi = total * (c + 1) / W
        deepest = order[-1]
        for (a, b, l) in spans:                  # last overlapping span wins
            if a < hi - 1e-9 and b > lo + 1e-9:
                deepest = l
        chunks.append((hi - lo, deepest))
    return chunks


def simulate_ring(
    trace: ModelTrace,
    workers: int = 32,
    bandwidth: float = 25e9,
    messaging: bool = True,
    multicast_phase2: bool = False,
    iterations: int = 3,
) -> SimResult:
    sim = E.Sim()
    W, bw = workers, bandwidth
    n = len(trace.layers)
    chunks = _ring_chunks(trace, W, messaging)
    markers = []
    model_ready: Dict[int, object] = {w: None for w in range(W)}

    for it in range(iterations):
        # fwd: not pipelined with distribution (§3.2); starts when the worker
        # has the full model from the previous iteration's second ring.
        fwd_done = {}
        for w in range(W):
            dep = model_ready[w]
            fwd_done[w] = _fwd_chain(
                sim, trace, w, it, lambda l: None,
                extra_deps=[dep] if dep is not None else [],
            )
        # global barrier before backprop (§8.2.1)
        bar = sim.add(("ringbar", it), deps=list(fwd_done.values()))
        grads = {w: _bp_chain(sim, trace, w, it, bar) for w in range(W)}

        have = {w: [] for w in range(W)}         # chunk arrival tasks per worker
        for c, (bits, ready_layer) in enumerate(chunks):
            if bits <= 0:
                continue
            owner = c % W
            # phase 1: reduce ring; hop k sends from s=(owner+1+k) to s+1
            prev = None
            for k in range(W - 1):
                s = (owner + 1 + k) % W
                r = (s + 1) % W
                deps = [grads[s][ready_layer]]
                if prev is not None:
                    deps.append(prev)
                prev = sim.add(
                    ("r1", it, c, k),
                    deps=deps,
                    resources=(E.egress(E.worker(s)), E.ingress(E.worker(r))),
                    duration=bits / bw,
                )
            reduced = prev if prev is not None else grads[owner][ready_layer]
            have[owner].append(reduced)
            # phase 2: distribute
            if multicast_phase2:
                t = sim.add(
                    ("r2mc", it, c),
                    deps=[reduced],
                    resources=(E.egress(E.worker(owner)),)
                    + tuple(E.ingress(E.worker(w)) for w in range(W) if w != owner),
                    duration=bits / bw,
                )
                for w in range(W):
                    if w != owner:
                        have[w].append(t)
            else:
                prev2 = reduced
                for k in range(W - 1):
                    s = (owner + k) % W
                    r = (s + 1) % W
                    prev2 = sim.add(
                        ("r2", it, c, k),
                        deps=[prev2],
                        resources=(E.egress(E.worker(s)), E.ingress(E.worker(r))),
                        duration=bits / bw,
                    )
                    have[r].append(prev2)

        for w in range(W):
            model_ready[w] = sim.add(("model", it, w), deps=have[w])
        markers.append(sim.add(("ringdone", it), deps=list(model_ready.values())))

    makespan = sim.run()
    marks = [sim.end_time[m] for m in markers]
    return SimResult(marks, makespan, sim, dict(mechanism="ring", W=W, bw=bw))


# ---------------------------------------------------------------------------
# butterfly mixing
# ---------------------------------------------------------------------------
def simulate_butterfly(
    trace: ModelTrace,
    workers: int = 32,
    bandwidth: float = 25e9,
    iterations: int = 3,
) -> SimResult:
    W, bw = workers, bandwidth
    assert W & (W - 1) == 0, "butterfly needs power-of-two workers"
    L = int(math.log2(W))
    sim = E.Sim()
    n = len(trace.layers)
    markers = []
    model_ready: Dict[int, object] = {w: None for w in range(W)}

    for it in range(iterations):
        fwd_done = {}
        for w in range(W):
            dep = model_ready[w]
            fwd_done[w] = _fwd_chain(
                sim, trace, w, it, lambda l: None,
                extra_deps=[dep] if dep is not None else [],
            )
        bar = sim.add(("bfbar", it), deps=list(fwd_done.values()))
        grads = {w: _bp_chain(sim, trace, w, it, bar) for w in range(W)}

        # bf(l, s, w): w's send of param l at stage s to partner w^2^s
        for l in range(n - 1, -1, -1):
            bits = trace.layers[l].size_bits
            for s in range(L):
                for w in range(W):
                    partner = w ^ (1 << s)
                    if s == 0:
                        deps = [grads[w][l]]
                    else:
                        q = w ^ (1 << (s - 1))
                        deps = [("bf", it, l, s - 1, w), ("bf", it, l, s - 1, q)]
                    sim.add(
                        ("bf", it, l, s, w),
                        deps=deps,
                        resources=(E.egress(E.worker(w)), E.ingress(E.worker(partner))),
                        duration=bits / bw,
                    )
        for w in range(W):
            q = w ^ (1 << (L - 1))
            model_ready[w] = sim.add(
                ("model", it, w),
                deps=[("bf", it, l, L - 1, q) for l in range(n)],
            )
        markers.append(sim.add(("bfdone", it), deps=list(model_ready.values())))

    makespan = sim.run()
    marks = [sim.end_time[m] for m in markers]
    return SimResult(marks, makespan, sim, dict(mechanism="butterfly", W=W, bw=bw))


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------
MECHANISMS = (
    "baseline",            # PS, no network support
    "agg",                 # PS + in-network aggregation
    "multicast",           # PS + multicast
    "multicast+agg",       # PS + both
    "ring",                # ring-reduce with messaging
    "ring_nomsg",          # ring-reduce, one ring per parameter
    "ring+multicast",      # multicast second ring
    "butterfly",
)


def simulate(mechanism: str, trace: ModelTrace, workers: int = 32,
             bandwidth: float = 25e9, **kw) -> SimResult:
    if mechanism == "baseline":
        return simulate_ps(trace, workers, bandwidth, **kw)
    if mechanism == "agg":
        return simulate_ps(trace, workers, bandwidth, in_network_agg=True, **kw)
    if mechanism == "multicast":
        return simulate_ps(trace, workers, bandwidth, multicast=True, **kw)
    if mechanism == "multicast+agg":
        return simulate_ps(trace, workers, bandwidth, multicast=True,
                           in_network_agg=True, **kw)
    if mechanism == "ring":
        return simulate_ring(trace, workers, bandwidth, messaging=True, **kw)
    if mechanism == "ring_nomsg":
        return simulate_ring(trace, workers, bandwidth, messaging=False, **kw)
    if mechanism == "ring+multicast":
        return simulate_ring(trace, workers, bandwidth, messaging=True,
                             multicast_phase2=True, **kw)
    if mechanism == "butterfly":
        return simulate_butterfly(trace, workers, bandwidth, **kw)
    raise ValueError(mechanism)


def speedup_table(trace: ModelTrace, mechanisms: Sequence[str],
                  workers: int = 32, bandwidth: float = 25e9, **kw):
    """Speedups relative to the no-network-support PS baseline (Tables 4/6)."""
    base = simulate("baseline", trace, workers, bandwidth, **kw).iteration_time
    out = {"baseline_s": base}
    for m in mechanisms:
        t = simulate(m, trace, workers, bandwidth, **kw).iteration_time
        out[m] = base / t
    return out
