"""Trace schema + the paper's four CNN subjects + JAX-derived traces.

A trace is network-agnostic (§5): per-parameter sizes and *relative* compute
times only.  The paper generated traces by instrumenting TensorFlow 1.4 send
ops; we reconstruct the four CNN traces from the paper's own aggregate tables
(Tables 2, 3, 7) and provide ``trace_from_cost_analysis`` to derive traces
for any of this framework's 10 architectures from the compiled step's cost
analysis — the modern analogue of the paper's collection pipeline.

Conventions: ``layers[0]`` is the FIRST layer of the network.  Backprop
visits layers in reverse; the paper's "first layer of backpropagation" extra
compute (Table 3 note) is ``bp_first_extra`` and attaches to the *last*
layer's gradient.  Sizes are bits on the wire; times are seconds.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerTrace:
    name: str
    size_bits: float
    fwd_time: float
    bp_time: float


@dataclasses.dataclass(frozen=True)
class ModelTrace:
    name: str
    layers: List[LayerTrace]
    bp_first_extra: float              # compute of the first backprop layer
    jitter: float = 0.02               # per-worker compute variation (fraction)

    @property
    def total_bits(self) -> float:
        return sum(l.size_bits for l in self.layers)

    @property
    def fwd_total(self) -> float:
        return sum(l.fwd_time for l in self.layers)

    @property
    def bp_total(self) -> float:
        return self.bp_first_extra + sum(l.bp_time for l in self.layers)

    def worker_scale(self, w: int) -> float:
        """Deterministic per-worker compute multiplier (natural staggering §4)."""
        if self.jitter == 0:
            return 1.0
        # low-discrepancy deterministic sequence in [-1, 1]
        u = ((w * 2654435761) % 1000) / 999.0 * 2 - 1
        return 1.0 + self.jitter * u

    def scaled(self, compute_factor: float = 1.0, name: str = "") -> "ModelTrace":
        """§8.6 'faster GPU': divide all compute times by ``compute_factor``."""
        layers = [
            LayerTrace(l.name, l.size_bits, l.fwd_time / compute_factor,
                       l.bp_time / compute_factor)
            for l in self.layers
        ]
        return dataclasses.replace(
            self, name=name or f"{self.name}-x{compute_factor}", layers=layers,
            bp_first_extra=self.bp_first_extra / compute_factor,
        )

    def with_synthetic_modules(self, kind: str, count: int) -> "ModelTrace":
        """§8.5 synthetic future models: insert modules before the last layer.

        ``compute`` modules mimic the 35x35x288 Inception block (expensive
        compute, modest weights); ``network`` modules mimic 17x17x768
        (heavier weights, cheap compute).
        """
        if kind == "compute":
            mod = LayerTrace("syn_c", 0.004e9, 0.004, 0.016)
        elif kind == "network":
            mod = LayerTrace("syn_n", 0.020e9, 0.002, 0.002)
        else:
            raise ValueError(kind)
        layers = list(self.layers)
        insert_at = max(len(layers) - 1, 0)
        for i in range(count):
            layers.insert(insert_at, dataclasses.replace(mod, name=f"{mod.name}{i}"))
        return dataclasses.replace(
            self, name=f"{self.name}+{count}{kind}", layers=layers
        )


def _spread(total: float, weights: Sequence[float]) -> List[float]:
    w = np.asarray(weights, float)
    w = w / w.sum()
    return list(total * w)


def _build(name, n_layers, total_bits, last_frac, fwd_total, bp_total,
           bp_first_extra, size_profile="rising", jitter=0.02) -> ModelTrace:
    """Synthesize a per-layer trace matching the paper's aggregates."""
    n = n_layers
    rest_bits = total_bits * (1 - last_frac)
    if size_profile == "rising":        # conv nets grow channels with depth
        weights = [1.0 + 3.0 * i / max(n - 2, 1) for i in range(n - 1)]
    else:                               # "even"
        weights = [1.0] * (n - 1)
    sizes = _spread(rest_bits, weights) + [total_bits * last_frac]
    # fwd cost roughly tracks compute-heavy early/middle layers
    fwd = _spread(fwd_total, [1.0] * n)
    bp = _spread(bp_total, [1.0] * n)
    layers = [
        LayerTrace(f"{name}/L{i}", sizes[i], fwd[i], bp[i]) for i in range(n)
    ]
    return ModelTrace(name, layers, bp_first_extra, jitter)


# ----------------------------------------------------------------------------
# The paper's four CNNs (Tables 2-3).  Notes:
#  * total size in Gb (gigabits) straight from Table 2;
#  * bp_net(25Gbps) in Table 3 equals size/25Gbps, confirming sizes are wire
#    bits;
#  * VGG16's fused FC parameter is 5.44 Gb of 6.58 Gb (Table 7 discussion) and
#    its backprop compute is dominated by that first backprop layer;
#  * Inception-v3 also carries a disproportionate final parameter (§8.2.1)
#    but its backprop stays compute-bound afterwards (compute:net 10.6).
# ----------------------------------------------------------------------------
INCEPTION_V3 = _build(
    "inception-v3", n_layers=21, total_bits=0.715e9, last_frac=0.30,
    fwd_total=0.176, bp_total=0.296, bp_first_extra=0.05,
)
VGG16 = _build(
    "vgg16", n_layers=22, total_bits=6.58e9, last_frac=5.44 / 6.58,
    fwd_total=0.169, bp_total=0.024, bp_first_extra=0.20,
)
RESNET_101 = _build(
    "resnet-101", n_layers=103, total_bits=1.42e9, last_frac=0.03,
    fwd_total=0.176, bp_total=0.180, bp_first_extra=0.02, size_profile="even",
)
RESNET_200 = _build(
    "resnet-200", n_layers=202, total_bits=2.06e9, last_frac=0.02,
    fwd_total=0.357, bp_total=0.340, bp_first_extra=0.04, size_profile="even",
)

PAPER_CNNS = {
    t.name: t for t in (INCEPTION_V3, VGG16, RESNET_101, RESNET_200)
}


# ----------------------------------------------------------------------------
# toy model of §8.1.1 / Fig 2: 3 ops, 3 s compute + 3 s network each.
# With 2 workers and 1 PS: baseline aggregation 21 s; in-network agg 12 s.
# ----------------------------------------------------------------------------
def toy_3op(compute=3.0, net_seconds=3.0, bw_bps=1e9) -> ModelTrace:
    bits = net_seconds * bw_bps
    layers = [LayerTrace(f"op{i}", bits, 0.0, compute) for i in range(3)]
    return ModelTrace("toy3", layers, bp_first_extra=0.0, jitter=0.0)


# ----------------------------------------------------------------------------
# modern trace source: derive a ModelTrace from this framework's own models.
# ----------------------------------------------------------------------------
def trace_from_cost_analysis(
    name: str,
    layer_param_counts: Sequence[int],
    layer_flops: Sequence[float],
    chip_flops_per_s: float = 197e12,
    wire_dtype_bits: int = 16,
    fwd_bp_ratio: float = 2.0,
    jitter: float = 0.02,
) -> ModelTrace:
    """Build a trace for an LM architecture from per-layer params/FLOPs.

    ``layer_flops`` are forward FLOPs; backprop compute is ``fwd_bp_ratio``x.
    This is the paper's trace-collection step re-seeded from compiled-model
    cost analysis (DESIGN.md §3).
    """
    layers = []
    for i, (pc, fl) in enumerate(zip(layer_param_counts, layer_flops)):
        layers.append(
            LayerTrace(
                f"{name}/L{i}",
                size_bits=pc * wire_dtype_bits,
                fwd_time=fl / chip_flops_per_s,
                bp_time=fwd_bp_ratio * fl / chip_flops_per_s,
            )
        )
    return ModelTrace(name, layers, bp_first_extra=0.0, jitter=jitter)
