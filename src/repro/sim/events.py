"""Discrete-event engine for the trace-driven training simulator (§5).

The model is a dependency DAG of *tasks*.  A task occupies one or more
*resources* (a worker's GPU, a NIC egress/ingress) for ``duration`` seconds,
and becomes ready when all of its dependencies have completed (plus an
optional offset — used by the in-network-aggregation cut-through model).

Resources are fluid full-duplex links: a transfer reserves the sender's
egress and the receiver's ingress for ``bits / bandwidth`` seconds, starting
at ``max(ready, free(resources...))``.  Tasks are admitted in ready-time
order (FIFO per resource), which reproduces the incast serialisation at a
parameter server's NIC that drives the paper's §4/§8 analysis.
"""
from __future__ import annotations

import heapq
import itertools
from collections import defaultdict
from typing import Dict, Hashable, Iterable, List, Optional, Tuple

Resource = Hashable
TaskId = Hashable


class Sim:
    def __init__(self) -> None:
        self._free: Dict[Resource, float] = defaultdict(float)
        self._deps_left: Dict[TaskId, int] = {}
        self._dep_ready: Dict[TaskId, float] = defaultdict(float)
        self._children: Dict[TaskId, List[TaskId]] = defaultdict(list)
        self._spec: Dict[TaskId, Tuple[Tuple[Resource, ...], float, float]] = {}
        self.end_time: Dict[TaskId, float] = {}
        self.start_time: Dict[TaskId, float] = {}
        self._heap: List[Tuple[float, int, TaskId]] = []
        self._seq = itertools.count()

    # ----------------------------------------------------------------- build
    def add(
        self,
        tid: TaskId,
        *,
        deps: Iterable[TaskId] = (),
        resources: Iterable[Resource] = (),
        duration: float = 0.0,
        ready_offset: float = 0.0,
        at: Optional[float] = None,
    ) -> TaskId:
        """Add a task.  ``at`` forces an absolute earliest-ready time."""
        if tid in self._spec:
            raise ValueError(f"duplicate task {tid!r}")
        deps = list(deps)
        self._spec[tid] = (tuple(resources), float(duration), float(ready_offset))
        self._deps_left[tid] = len(deps)
        if at is not None:
            self._dep_ready[tid] = float(at)
        for d in deps:
            if d in self.end_time:
                self._deps_left[tid] -= 1
                self._dep_ready[tid] = max(self._dep_ready[tid], self.end_time[d])
            else:
                self._children[d].append(tid)
        if self._deps_left[tid] == 0:
            self._push(tid)
        return tid

    def _push(self, tid: TaskId) -> None:
        _, _, offset = self._spec[tid]
        ready = self._dep_ready[tid] + offset
        heapq.heappush(self._heap, (ready, next(self._seq), tid))

    # ------------------------------------------------------------------- run
    def run(self) -> float:
        """Execute all tasks; returns the makespan."""
        makespan = 0.0
        while self._heap:
            ready, _, tid = heapq.heappop(self._heap)
            resources, duration, _ = self._spec[tid]
            start = ready
            for r in resources:
                start = max(start, self._free[r])
            end = start + duration
            for r in resources:
                self._free[r] = end
            self.start_time[tid] = start
            self.end_time[tid] = end
            makespan = max(makespan, end)
            for c in self._children.pop(tid, ()):  # release dependents
                self._dep_ready[c] = max(self._dep_ready[c], end)
                self._deps_left[c] -= 1
                if self._deps_left[c] == 0:
                    self._push(c)
        undone = [t for t, n in self._deps_left.items() if n > 0]
        if undone:
            raise RuntimeError(f"deadlock: {len(undone)} tasks never ready, e.g. {undone[:5]}")
        return makespan

    # ----------------------------------------------------------------- query
    def t(self, tid: TaskId) -> float:
        return self.end_time[tid]

    def max_end(self, tids: Iterable[TaskId]) -> float:
        return max(self.end_time[t] for t in tids)


# canonical resource names ----------------------------------------------------
def gpu(w: int) -> str:
    return f"gpu/{w}"


def egress(node: str) -> str:
    return f"eg/{node}"


def ingress(node: str) -> str:
    return f"in/{node}"


def worker(w: int) -> str:
    return f"w{w}"


def ps(p: int) -> str:
    return f"ps{p}"
