"""Model assembly: init / forward / loss / prefill / decode for all 10 archs.

Layer stacks are organised as ``G`` groups of ``P`` layers, where ``P`` is the
least common multiple of the arch's interleave patterns (gemma2 local/global:
2, jamba attn:mamba + MoE: 8, everything else: 1).  Groups are homogeneous, so
the stack is a single rematerialised ``lax.scan`` over stacked group params —
this keeps the HLO size O(P) instead of O(num_layers), which is what makes the
126-layer llama3-405b cell compilable.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib

Params = Dict[str, Any]
PyTree = Any

TOKEN_LOSS_CHUNK = 8192


# =========================================================================
# structure
# =========================================================================
def layer_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.attn_layer_period:
        p = math.lcm(p, cfg.attn_layer_period)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.layer_period)
    if cfg.local_global_period:
        p = math.lcm(p, cfg.local_global_period)
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p


def num_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // layer_period(cfg)


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0 or cfg.moe is not None


# =========================================================================
# init
# =========================================================================
def _init_one_layer(key, cfg: ModelConfig, j: int, *, decoder_cross: bool) -> Params:
    ks = jax.random.split(key, 8)
    sub: Params = {}
    if cfg.is_attn_layer(j):
        sub["ln_attn"] = L.init_rms_norm(cfg.d_model)
        sub["attn"] = attn_lib.init_attention(ks[0], cfg)
        if cfg.post_block_norm:
            sub["ln_attn_post"] = L.init_rms_norm(cfg.d_model)
        if decoder_cross:
            sub["ln_cross"] = L.init_rms_norm(cfg.d_model)
            sub["cross"] = attn_lib.init_attention(ks[1], cfg, cross=True)
    elif cfg.ssm is not None:
        sub["ln_mamba"] = L.init_rms_norm(cfg.d_model)
        sub["mamba"] = mamba_lib.init_mamba(ks[2], cfg)
    if cfg.is_moe_layer(j):
        sub["ln_ffn"] = L.init_rms_norm(cfg.d_model)
        sub["moe"] = moe_lib.init_moe(ks[3], cfg)
        if cfg.post_block_norm:
            sub["ln_ffn_post"] = L.init_rms_norm(cfg.d_model)
    elif cfg.d_ff > 0:
        sub["ln_ffn"] = L.init_rms_norm(cfg.d_model)
        sub["mlp"] = L.init_mlp(ks[4], cfg)
        if cfg.post_block_norm:
            sub["ln_ffn_post"] = L.init_rms_norm(cfg.d_model)
    return sub


def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": L.init_rms_norm(cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg),
        "ln_ffn": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> Params:
    P = layer_period(cfg)
    G = num_groups(cfg)
    k_embed, k_head, k_layers, k_enc = jax.random.split(key, 4)
    params: Params = {"embed": L.init_embedding(k_embed, cfg)}

    groups = []
    for g, kg in enumerate(jax.random.split(k_layers, G)):
        sub_keys = jax.random.split(kg, P)
        group = {
            f"sub{j}": _init_one_layer(
                sub_keys[j], cfg, j, decoder_cross=cfg.is_encoder_decoder
            )
            for j in range(P)
        }
        groups.append(group)
    params["blocks"] = _stack(groups)
    params["final_norm"] = L.init_rms_norm(cfg.d_model)

    if cfg.is_encoder_decoder:
        enc_groups = [
            _init_enc_layer(k, cfg) for k in jax.random.split(k_enc, cfg.encoder_layers)
        ]
        params["enc_blocks"] = _stack(enc_groups)
        params["enc_final_norm"] = L.init_rms_norm(cfg.d_model)

    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(k_head, cfg)
    return params


def head_table(params: Params) -> jax.Array:
    return (params.get("lm_head") or params["embed"])["table"]


# =========================================================================
# layer application (full-sequence mode)
# =========================================================================
def _apply_layer(
    sub: Params,
    x: jax.Array,
    cfg: ModelConfig,
    j: int,
    positions: jax.Array,
    memory: Optional[jax.Array],
    use_flash: bool,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, jax.Array]]]:
    """Returns (x, aux_loss, kv-or-None)."""
    from repro.models.perf import residual_constraint, sublayer_barrier

    x = residual_constraint(x)
    aux = jnp.zeros((), jnp.float32)
    kv = None
    if "attn" in sub:
        h, kv = attn_lib.attention(
            sub["attn"],
            L.rms_norm(x, sub["ln_attn"]["scale"], cfg.norm_eps),
            cfg,
            local=cfg.is_local_layer(j),
            positions=positions,
            use_flash=use_flash,
        )
        h = sublayer_barrier(h)
        if "ln_attn_post" in sub:
            h = L.rms_norm(h, sub["ln_attn_post"]["scale"], cfg.norm_eps)
        x = x + h
        if "cross" in sub and memory is not None:
            mem_kv = attn_lib.encode_memory_kv(sub["cross"], memory, cfg)
            h = attn_lib.cross_attention(
                sub["cross"],
                L.rms_norm(x, sub["ln_cross"]["scale"], cfg.norm_eps),
                mem_kv,
                cfg,
            )
            x = x + sublayer_barrier(h)
    elif "mamba" in sub:
        h = mamba_lib.mamba_forward(
            sub["mamba"], L.rms_norm(x, sub["ln_mamba"]["scale"], cfg.norm_eps), cfg
        )
        x = x + sublayer_barrier(h)
    if "moe" in sub:
        h, aux = moe_lib.moe_ffn(
            sub["moe"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg
        )
        h = sublayer_barrier(h)
        if "ln_ffn_post" in sub:
            h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
        x = x + h
    elif "mlp" in sub:
        h = L.mlp(sub["mlp"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg)
        h = sublayer_barrier(h)
        if "ln_ffn_post" in sub:
            h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
        x = x + h
    return x, aux, kv


def _encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(jnp.dtype(cfg.compute_dtype))
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, blk):
        h, _ = attn_lib.attention(
            blk["attn"],
            L.rms_norm(x, blk["ln_attn"]["scale"], cfg.norm_eps),
            cfg,
            positions=positions,
            causal=False,
        )
        x = x + h
        x = x + L.mlp(blk["mlp"], L.rms_norm(x, blk["ln_ffn"]["scale"], cfg.norm_eps), cfg)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_final_norm"]["scale"], cfg.norm_eps)


def forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    memory: Optional[jax.Array] = None,
    use_flash: bool = False,
    collect_kv: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[PyTree]]:
    """Full-sequence decoder pass.

    Returns (hidden (B,S,d), total aux loss, stacked per-group kv if requested).
    ``memory``: encoder output for enc-dec archs.
    """
    P = layer_period(cfg)
    x = L.embed(params["embed"], tokens, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(carry, group):
        x, aux = carry
        kvs = {}
        for j in range(P):
            x, a, kv = _apply_layer(
                group[f"sub{j}"], x, cfg, j, positions, memory, use_flash
            )
            aux = aux + a
            if collect_kv and kv is not None:
                kvs[f"sub{j}"] = kv
        return (x, aux), (kvs if collect_kv else None)

    (x, aux), kv_stack = jax.lax.scan(
        jax.checkpoint(body), (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return x, aux, kv_stack


# =========================================================================
# loss (seq-chunked cross entropy: never materialises (B,S,V) at once)
# =========================================================================
def chunked_xent(
    table: jax.Array,
    x: jax.Array,
    labels: jax.Array,
    cfg: ModelConfig,
    z_loss: float = 1e-4,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d), labels: (B,S) with -1 = ignore.  Returns (sum_nll, n_tokens)."""
    from repro.models.perf import FLAGS, constraint

    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    lf = labels.reshape(T)
    C = min(TOKEN_LOSS_CHUNK, T)
    if T % C:
        C = T
    n = T // C
    if FLAGS["loss_sharding"] and FLAGS["mesh"] is not None:
        # keep tokens sharded over the batch axes within every chunk; GSPMD
        # otherwise replicates chunks and all-reduces f32 logits (§Perf H1)
        ba = FLAGS["batch_axes"]
        xf = constraint((None, ba, None))(xf.reshape(n, C, d)).reshape(T, d)
        lf = constraint((None, ba))(lf.reshape(n, C)).reshape(T)

    @jax.checkpoint
    def chunk(carry, inp):
        nll_sum, cnt = carry
        xc, lc = inp
        logits = jnp.einsum("td,vd->tv", xc, table, preferred_element_type=jnp.float32)
        if cfg.final_logit_softcap:
            logits = L.softcap(logits, cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[:, None], axis=1)[:, 0]
        valid = (lc >= 0).astype(jnp.float32)
        nll = (lse - gold + z_loss * jnp.square(lse)) * valid
        return (nll_sum + jnp.sum(nll), cnt + jnp.sum(valid)), None

    (nll_sum, cnt), _ = jax.lax.scan(
        chunk,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xf.reshape(n, C, d), lf.reshape(n, C)),
    )
    return nll_sum, cnt


def loss_fn(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    use_flash: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    memory = None
    if cfg.is_encoder_decoder:
        memory = _encode(params, batch["frames"], cfg)
    x, aux, _ = forward(params, batch["tokens"], cfg, memory=memory, use_flash=use_flash)
    nll_sum, cnt = chunked_xent(head_table(params), x, batch["labels"], cfg)
    ce = nll_sum / jnp.maximum(cnt, 1.0)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux, "tokens": cnt}


# =========================================================================
# serving: prefill + single-token decode
# =========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Zero-initialised decode cache (used for dry-run decode cells)."""
    P = layer_period(cfg)
    G = num_groups(cfg)

    def one_group():
        c = {}
        for j in range(P):
            if cfg.is_attn_layer(j):
                c[f"sub{j}"] = attn_lib.init_kv_cache(
                    cfg, batch, max_len, cfg.is_local_layer(j)
                )
            elif cfg.ssm is not None:
                c[f"sub{j}"] = mamba_lib.init_mamba_state(cfg, batch)
        return c

    layers = _stack([one_group() for _ in range(G)])
    cache: PyTree = {"layers": layers, "pos": jnp.zeros((), jnp.int32)}
    if cfg.is_encoder_decoder:
        Hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dt = jnp.dtype(cfg.compute_dtype)
        cache["memory_kv"] = {
            f"{j}": _stack(
                [
                    {
                        "k": jnp.zeros((batch, max_len, Hk, hd), dt),
                        "v": jnp.zeros((batch, max_len, Hk, hd), dt),
                    }
                    for _ in range(G)
                ]
            )
            for j in range(P)
            if cfg.is_attn_layer(j)
        }
    return cache


def prefill(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    max_len: int,
    *,
    use_flash: bool = False,
) -> Tuple[jax.Array, PyTree]:
    """Process the prompt; return (last-position logits (B,V), decode cache)."""
    P = layer_period(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    memory = None
    if cfg.is_encoder_decoder:
        memory = _encode(params, batch["frames"], cfg)
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(S)[None, :]

    def body(carry, group):
        x = carry
        states = {}
        for j in range(P):
            sub = group[f"sub{j}"]
            if "attn" in sub:
                h, kv = attn_lib.attention(
                    sub["attn"],
                    L.rms_norm(x, sub["ln_attn"]["scale"], cfg.norm_eps),
                    cfg,
                    local=cfg.is_local_layer(j),
                    positions=positions,
                    use_flash=use_flash,
                )
                if "ln_attn_post" in sub:
                    h = L.rms_norm(h, sub["ln_attn_post"]["scale"], cfg.norm_eps)
                x = x + h
                states[f"sub{j}"] = attn_lib.cache_from_prefill(
                    kv, cfg, max_len, cfg.is_local_layer(j)
                )
                if "cross" in sub and memory is not None:
                    mem_kv = attn_lib.encode_memory_kv(sub["cross"], memory, cfg)
                    states[f"mem{j}"] = mem_kv
                    h = attn_lib.cross_attention(
                        sub["cross"],
                        L.rms_norm(x, sub["ln_cross"]["scale"], cfg.norm_eps),
                        mem_kv,
                        cfg,
                    )
                    x = x + h
            elif "mamba" in sub:
                h, st = mamba_lib.state_from_prefill(
                    sub["mamba"],
                    L.rms_norm(x, sub["ln_mamba"]["scale"], cfg.norm_eps),
                    cfg,
                )
                x = x + h
                states[f"sub{j}"] = st
            if "moe" in sub:
                h, _ = moe_lib.moe_ffn(
                    sub["moe"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg
                )
                if "ln_ffn_post" in sub:
                    h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
                x = x + h
            elif "mlp" in sub:
                h = L.mlp(
                    sub["mlp"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg
                )
                if "ln_ffn_post" in sub:
                    h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
                x = x + h
        return x, states

    x, states = jax.lax.scan(jax.checkpoint(body), x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    last = x[:, -1, :]
    logits = L.unembed({"table": head_table(params)}, last, cfg)

    layers = {k: v for k, v in states.items() if not k.startswith("mem")}
    cache: PyTree = {"layers": layers, "pos": jnp.full((), S, jnp.int32)}
    if cfg.is_encoder_decoder:
        cache["memory_kv"] = {k[3:]: v for k, v in states.items() if k.startswith("mem")}
    return logits, cache


def decode_step(
    params: Params,
    token: jax.Array,
    cache: PyTree,
    cfg: ModelConfig,
) -> Tuple[jax.Array, PyTree]:
    """One decode step.  token: (B, 1) int32.  Returns (logits (B,V), new cache)."""
    P = layer_period(cfg)
    pos = cache["pos"]
    x = L.embed(params["embed"], token, cfg)

    xs = (params["blocks"], cache["layers"])
    if cfg.is_encoder_decoder:
        xs = xs + (cache["memory_kv"],)

    def body(x, scanned):
        group, states = scanned[0], scanned[1]
        mem_kv = scanned[2] if cfg.is_encoder_decoder else None
        new_states = {}
        for j in range(P):
            sub = group[f"sub{j}"]
            if "attn" in sub:
                h, new_kv = attn_lib.attention_decode(
                    sub["attn"],
                    L.rms_norm(x, sub["ln_attn"]["scale"], cfg.norm_eps),
                    states[f"sub{j}"],
                    pos,
                    cfg,
                    local=cfg.is_local_layer(j),
                )
                if "ln_attn_post" in sub:
                    h = L.rms_norm(h, sub["ln_attn_post"]["scale"], cfg.norm_eps)
                x = x + h
                new_states[f"sub{j}"] = new_kv
                if "cross" in sub and mem_kv is not None:
                    mj = mem_kv[f"{j}"]
                    h = attn_lib.cross_attention(
                        sub["cross"],
                        L.rms_norm(x, sub["ln_cross"]["scale"], cfg.norm_eps),
                        mj,
                        cfg,
                    )
                    x = x + h
            elif "mamba" in sub:
                h, st = mamba_lib.mamba_step(
                    sub["mamba"],
                    L.rms_norm(x, sub["ln_mamba"]["scale"], cfg.norm_eps),
                    states[f"sub{j}"],
                    cfg,
                )
                x = x + h
                new_states[f"sub{j}"] = st
            if "moe" in sub:
                h, _ = moe_lib.moe_ffn(
                    sub["moe"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg
                )
                if "ln_ffn_post" in sub:
                    h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
                x = x + h
            elif "mlp" in sub:
                h = L.mlp(
                    sub["mlp"], L.rms_norm(x, sub["ln_ffn"]["scale"], cfg.norm_eps), cfg
                )
                if "ln_ffn_post" in sub:
                    h = L.rms_norm(h, sub["ln_ffn_post"]["scale"], cfg.norm_eps)
                x = x + h
        return x, new_states

    x, new_layers = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = L.unembed({"table": head_table(params)}, x[:, -1, :], cfg)
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = pos + 1
    return logits, new_cache
