"""Performance-iteration flags (§Perf hillclimbs, EXPERIMENTS.md).

Each flag is one hypothesis->change from the roofline loop, OFF by default so
the paper-faithful/GSPMD-naive baseline stays measurable:

``loss_sharding``   keep the token dimension of the chunked cross-entropy
                    sharded over the batch axes (with_sharding_constraint),
                    instead of letting GSPMD replicate each chunk and
                    all-reduce f32 logits (observed 40 GB/chip on
                    qwen/train_4k).
``bf16_grad_accum`` accumulate/reduce gradients in bf16 instead of f32 —
                    halves gradient-sync wire bytes; fp32 master weights in
                    the optimizer keep the update math exact.
``norm_bf16_bwd``   custom-vjp RMSNorm that emits bf16 input cotangents, so
                    backward TP all-reduces run at bf16 width instead of the
                    f32 internal dtype (observed 3x f32[B,S,d] tuples per
                    layer).
``grad_zero1``      constrain gradients to the zero-1 (data-sharded) layout so
                    GSPMD reduce-scatters instead of all-reducing, matching
                    the sharded optimizer state.
``moe_ep``          constrain the MoE dispatch buffer to expert-parallel
                    sharding so dispatch becomes an all-to-all instead of
                    gather+replicate.

Flags are process-global (set before tracing).  ``mesh``/``batch_axes`` give
the constraint context.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

FLAGS = {
    "loss_sharding": False,
    "bf16_grad_accum": False,
    "norm_bf16_bwd": False,
    "grad_zero1": False,
    "moe_ep": False,
    "attn_sharding": False,   # pin q/k/v + attn output layouts (§Perf H5)
    "bf16_cotangents": False,  # dtype barriers at attn boundaries (§Perf H6)
    "opt_barriers": False,     # stop f32 convert-hoist through psums (§Perf H7)
    "act_sharding": False,     # pin residual stream to P(batch,None,None) (§Perf H8)
    "moe_local_dispatch": False,  # per-data-shard MoE routing via shard_map (§Perf H10)
    "mesh": None,
    "batch_axes": ("data",),
}


def residual_constraint(x):
    """§Perf H8: pin the (B, S, d) residual stream at layer boundaries.

    With FSDP weights (d over 'data', heads/ffn over 'model') GSPMD invents
    mixed activation shardings and reshards per sublayer (observed: W=8
    all-to-alls + W=2 all-gathers per layer per microbatch on llama3-405b).
    Pinning the boundary layout to pure batch sharding makes every sublayer a
    clean TP block: all-gather weights in, psum activations out.
    """
    if not FLAGS["act_sharding"] or FLAGS["mesh"] is None:
        return x
    if FLAGS["act_sharding"] == "sp":
        # Megatron-style sequence parallelism: shard S over 'model' at the
        # boundary; GSPMD then emits all-gather(S) into each TP sublayer and
        # reduce-scatter(S) out — half the wire of two full psums (§Perf H9)
        return constraint((FLAGS["batch_axes"], "model", None))(x)
    return constraint((FLAGS["batch_axes"], None, None))(x)


def sublayer_barrier(x):
    """§Perf H7: XLA's algebraic simplifier rewrites convert(all-reduce(bf16))
    into all-reduce(convert(f32)) — doubling TP wire bytes because the next
    consumer is the fp32 RMSNorm.  An optimization_barrier directly after the
    TP-reduced einsum pins the all-reduce to the bf16 tensor."""
    import jax

    if not FLAGS["opt_barriers"]:
        return x
    return jax.lax.optimization_barrier(x)


def set_flags(**kw) -> None:
    for k, v in kw.items():
        if k not in FLAGS:
            raise KeyError(k)
        FLAGS[k] = v


@contextmanager
def perf_flags(**kw):
    old = {k: FLAGS[k] for k in kw}
    set_flags(**kw)
    try:
        yield
    finally:
        FLAGS.update(old)


def cast_bwd(x):
    """Identity forward; backward casts the cotangent to the primal dtype.

    §Perf H6: cotangents widen to f32 through the f32-softmax boundary (f32
    grad x bf16 primal promotes), and the f32 then rides the backward TP
    all-reduces, doubling their wire bytes.  A dtype barrier at the q/k/v and
    attention-output boundaries keeps backward collectives at bf16 — the fp32
    softmax math itself is untouched.
    """
    import jax

    if not FLAGS["bf16_cotangents"]:
        return x
    dt = x.dtype   # captured statically in the closure (not a residual)

    @jax.custom_vjp
    def _barrier(y):
        return y

    def _fwd(y):
        return y, None

    def _bwd(_, g):
        return (g.astype(dt),)

    _barrier.defvjp(_fwd, _bwd)
    return _barrier(x)


def constraint(spec_args: Tuple):
    """with_sharding_constraint helper; no-op when no mesh is configured."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = FLAGS["mesh"]
    if mesh is None:
        return lambda x: x
    sh = NamedSharding(mesh, P(*spec_args))
    return lambda x: jax.lax.with_sharding_constraint(x, sh)
