"""Model zoo: all 10 assigned architectures via a single assembly path."""
from repro.models.model import (
    decode_step,
    forward,
    init_cache,
    init_params,
    layer_period,
    loss_fn,
    num_groups,
    prefill,
)

__all__ = [
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "layer_period",
    "loss_fn",
    "num_groups",
    "prefill",
]
