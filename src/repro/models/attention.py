"""GQA attention: q-chunked training/prefill path, cached decode path.

Features covered (union of the 10 assigned archs): grouped-query attention,
RoPE, QKV bias, QK-norm, sliding-window (rolling cache), gemma2 local/global
alternation, attention logit soft-capping, cross-attention (enc-dec).

The training/prefill path is **q-chunked**: a ``lax.scan`` over query chunks
with ``jax.checkpoint`` per chunk keeps the materialised score tensor at
(B, H, chunk, S) instead of (B, H, S, S) — this is the XLA-path analogue of
the Pallas flash kernel in ``repro/kernels`` (which can be swapped in with
``use_flash=True``) and is what makes the 4k/32k dry-run cells memory-sane.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm

Params = Dict[str, Any]

DEFAULT_Q_CHUNK = 512
NEG_INF = -2.0e38


# ----------------------------------------------------------------------- params
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, H, Hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, H, hd), in_axis=0, dtype=dt),
        "wk": dense_init(ks[1], (d, Hk, hd), in_axis=0, dtype=dt),
        "wv": dense_init(ks[2], (d, Hk, hd), in_axis=0, dtype=dt),
        "wo": dense_init(ks[3], (H, hd, d), in_axis=0, dtype=dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hk, hd), dt)
        p["bv"] = jnp.zeros((Hk, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array],
    rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,Hk,hd); RoPE applied to q,k."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models.perf import cast_bwd

    q, k, v = cast_bwd(q), cast_bwd(k), cast_bwd(v)
    q, k, v = _constrain_qkv(q, k, v, cfg)
    return q, k, v


def _constrain_qkv(q, k, v, cfg: ModelConfig):
    """§Perf H5: pin (B, S, H, hd) layouts — batch over the batch axes, heads
    over 'model' (head_dim when heads don't divide) — so GSPMD never invents
    kv-sequence-sharded attention with f32 cross-shard reductions."""
    from repro.models.perf import FLAGS, constraint

    mesh = FLAGS["mesh"]
    if not FLAGS["attn_sharding"] or mesh is None:
        return q, k, v
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1)
    ba = FLAGS["batch_axes"]
    nb = int(np.prod([sizes.get(a, 1) for a in ba]))

    def spec_for(x):
        B, S, H, hd = x.shape
        bspec = ba if B % max(nb, 1) == 0 else None
        if H % msize == 0:
            return (bspec, None, "model", None)
        if hd % msize == 0:
            return (bspec, None, None, "model")
        return (bspec, None, None, None)

    return (constraint(spec_for(q))(q), constraint(spec_for(k))(k),
            constraint(spec_for(v))(v))


def _scores_to_probs(scores: jax.Array, mask: jax.Array, softcap: float) -> jax.Array:
    scores = scores.astype(jnp.float32)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


# ------------------------------------------------------------------- full pass
def attention(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool = False,
    positions: Optional[jax.Array] = None,
    q_chunk: int = DEFAULT_Q_CHUNK,
    use_flash: bool = False,
    causal: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Self-attention over a full sequence (causal by default; encoders pass
    ``causal=False``).

    Returns (output (B,S,d), kv dict for cache construction).
    ``local`` selects the sliding window (for SWA / gemma2 local layers).
    """
    B, S, _ = x.shape
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    window = cfg.sliding_window if local and cfg.sliding_window else 0

    if use_flash and causal:
        from repro.kernels.flash_attention import ops as flash_ops

        out = flash_ops.flash_attention(
            q, k, v,
            causal=True,
            window=window,
            softcap=cfg.attn_logit_softcap,
        )
    else:
        out = _chunked_attention(
            q, k, v, window, cfg.attn_logit_softcap, q_chunk, causal
        )
    from repro.models.perf import cast_bwd

    out = cast_bwd(out.astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, {"k": k, "v": v}


def _chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    window: int,
    softcap: float,
    q_chunk: int,
    causal: bool = True,
) -> jax.Array:
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(hd)
    C = min(q_chunk, S)
    if S % C:
        C = S  # fall back to unchunked for ragged smoke shapes
    n_chunks = S // C

    # GQA convention: consecutive q heads share a kv head (kv = h // G)
    qg = (q * scale).reshape(B, n_chunks, C, Hk, G, hd)
    kv_pos = jnp.arange(S)

    @jax.checkpoint
    def one_chunk(carry, inputs):
        qc, q0 = inputs                         # (B, C, Hk, G, hd), scalar
        q_pos = q0 + jnp.arange(C)
        if causal:
            mask = kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
        else:
            mask = jnp.ones((C, S), bool)
        s = jnp.einsum("bchgk,bshk->bhgcs", qc, k)
        p = _scores_to_probs(s, mask[None, None, None, :, :], softcap)
        o = jnp.einsum("bhgcs,bshk->bchgk", p.astype(v.dtype), v)
        return carry, o

    starts = jnp.arange(n_chunks) * C
    _, out = jax.lax.scan(
        one_chunk, None, (qg.swapaxes(0, 1), starts)
    )  # out: (n_chunks, B, C, Hk, G, hd)
    out = out.swapaxes(0, 1).reshape(B, S, H, hd)
    return out


# ------------------------------------------------------------------ cross attn
def cross_attention(
    params: Params,
    x: jax.Array,
    memory_kv: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
    k, v = memory_kv["k"], memory_kv["v"]
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    B, S, _, _ = q.shape
    G = H // Hk
    qg = (q / np.sqrt(hd)).reshape(B, S, Hk, G, hd)
    s = jnp.einsum("bchgk,bshk->bhgcs", qg, k)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhgcs,bshk->bchgk", p.astype(v.dtype), v).reshape(B, S, H, hd)
    return jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])


def encode_memory_kv(params: Params, memory: jax.Array, cfg: ModelConfig) -> Dict[str, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return {"k": k, "v": v}


# --------------------------------------------------------------------- decode
def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, local: bool
) -> Dict[str, jax.Array]:
    """Cache slots.  Rolling (size=window) for local/SWA layers."""
    Hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    C = min(cfg.sliding_window, max_len) if (local and cfg.sliding_window) else max_len
    dt = jnp.dtype(cfg.compute_dtype)
    return {
        "k": jnp.zeros((batch, C, Hk, hd), dt),
        "v": jnp.zeros((batch, C, Hk, hd), dt),
    }


def cache_from_prefill(
    kv: Dict[str, jax.Array], cfg: ModelConfig, max_len: int, local: bool
) -> Dict[str, jax.Array]:
    """Arrange prefill K/V into decode cache slots (slot(p) = p mod C)."""
    k, v = kv["k"], kv["v"]
    B, S = k.shape[:2]
    C = min(cfg.sliding_window, max_len) if (local and cfg.sliding_window) else max_len
    if C >= S:
        pad = [(0, 0), (0, C - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    # slot i holds the newest position p < S with p mod C == i
    i = jnp.arange(C)
    p = S - 1 - ((S - 1 - i) % C)
    return {"k": k[:, p], "v": v[:, p]}


def attention_decode(
    params: Params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    pos: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 (tokens so far).

    Writes the new K/V at slot ``pos mod C`` then attends over the cache.
    RoPE'd keys are stored, so no absolute positions are needed at read time.
    """
    B = x.shape[0]
    H, Hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)

    C = cache["k"].shape[1]
    slot = jnp.mod(pos, C)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    G = H // Hk
    qg = (q / np.sqrt(hd)).reshape(B, Hk, G, hd)
    s = jnp.einsum("bhgk,bshk->bhgs", qg, ck)            # (B, Hk, G, C)
    valid = jnp.arange(C)[None, None, None, :] <= pos    # cold-start masking
    p = _scores_to_probs(s, valid, cfg.attn_logit_softcap)
    o = jnp.einsum("bhgs,bshk->bhgk", p.astype(cv.dtype), cv).reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype), params["wo"])
    return y, {"k": ck, "v": cv}
