"""Mamba-1 selective-state-space block (falcon-mamba, jamba).

Training/prefill uses a **chunked associative scan**: ``lax.scan`` over
sequence chunks (rematerialised) carrying the (B, d_inner, N) state, with a
parallel ``associative_scan`` inside each chunk.  This bounds the
materialised state history to one chunk — the XLA-path analogue of the
Pallas ``mamba_scan`` kernel (swap in with ``use_kernel=True``).

Decode is a single-step recurrence over a constant-size state — this is what
makes ``long_500k`` native for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]

DEFAULT_CHUNK = 256


def init_mamba(key, cfg: ModelConfig) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    N = s.state_size
    R = s.resolved_dt_rank(d)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dt),
        "conv_w": dense_init(ks[1], (s.conv_width, di), dtype=dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dtype=dt),
        "dt_proj": dense_init(ks[3], (R, di), dtype=dt),
        "dt_bias": jnp.full((di,), np.log(np.expm1(0.01)), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dt),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, di); w: (W, di).  Left-padded causal depthwise conv.

    Accumulates in fp32 (and the decode path mirrors the same order) so that
    the step recurrence tracks the full-sequence path bit-for-bit as far as
    bf16 storage allows.
    """
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0))).astype(jnp.float32)
    S = x.shape[1]
    wf = w.astype(jnp.float32)
    out = xp[:, 0:S, :] * wf[0]
    for j in range(1, W):
        out = out + xp[:, j : j + S, :] * wf[j]
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(params: Params, x_conv: jax.Array, cfg: ModelConfig):
    """Project conv output to (dt, B, C) selective parameters (fp32)."""
    s = cfg.ssm
    N = s.state_size
    R = s.resolved_dt_rank(cfg.d_model)
    proj = x_conv @ params["x_proj"]                               # (B,S,R+2N)
    dt_r, B_, C_ = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )                                                              # (B,S,di)
    A = -jnp.exp(params["A_log"])                                  # (di,N)
    return dt, A, B_.astype(jnp.float32), C_.astype(jnp.float32)


def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
    h0: jax.Array,
    chunk: int = DEFAULT_CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t

    x, dt: (B,S,di); A: (di,N); B_, C_: (B,S,N); h0: (B,di,N) fp32.
    Returns (y (B,S,di) fp32, h_final).
    """
    B, S, di = x.shape
    N = A.shape[1]
    ch = min(chunk, S)
    if S % ch:
        ch = S
    nc = S // ch

    a = jnp.exp(dt[..., None] * A)                                 # (B,S,di,N)
    bx = (dt * x.astype(jnp.float32))[..., None] * B_[:, :, None, :]
    a = a.reshape(B, nc, ch, di, N).swapaxes(0, 1)
    bx = bx.reshape(B, nc, ch, di, N).swapaxes(0, 1)
    c = C_.reshape(B, nc, ch, N).swapaxes(0, 1)

    def combine(left, right):
        (al, bl), (ar, br) = left, right
        return al * ar, ar * bl + br

    @jax.checkpoint
    def chunk_step(h, inputs):
        ac, bxc, cc = inputs                                       # (B,ch,di,N)...
        pa, pb = jax.lax.associative_scan(combine, (ac, bxc), axis=1)
        h_all = pa * h[:, None] + pb                               # (B,ch,di,N)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, cc)
        return h_all[:, -1], y

    h_final, y = jax.lax.scan(chunk_step, h0, (a, bx, c))
    y = y.swapaxes(0, 1).reshape(B, S, di)
    return y, h_final


def mamba_forward(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    chunk: int = DEFAULT_CHUNK,
    use_kernel: bool = False,
) -> jax.Array:
    """Full-sequence Mamba block.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    s = cfg.ssm
    di = s.expand * d
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_depthwise_conv(x_in, params["conv_w"], params["conv_b"]))
    dt, A, B_, C_ = _ssm_inputs(params, x_conv, cfg)
    h0 = jnp.zeros((B, di, s.state_size), jnp.float32)
    if use_kernel:
        from repro.kernels.mamba_scan import ops as scan_ops

        y, _ = scan_ops.selective_scan(x_conv.astype(jnp.float32), dt, A, B_, C_, h0)
    else:
        y, _ = selective_scan(x_conv.astype(jnp.float32), dt, A, B_, C_, h0, chunk)
    y = y + params["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["out_proj"]


# ------------------------------------------------------------------- decode
def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, di), jnp.dtype(cfg.compute_dtype)),
        "ssm": jnp.zeros((batch, di, s.state_size), jnp.float32),
    }


def state_from_prefill(
    params: Params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the full-sequence path AND return the decode state at position S-1."""
    B, S, d = x.shape
    s = cfg.ssm
    di = s.expand * d
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_depthwise_conv(x_in, params["conv_w"], params["conv_b"]))
    dt, A, B_, C_ = _ssm_inputs(params, x_conv, cfg)
    h0 = jnp.zeros((B, di, s.state_size), jnp.float32)
    y, h_final = selective_scan(x_conv.astype(jnp.float32), dt, A, B_, C_, h0)
    y = y + params["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    conv_tail = x_in[:, S - (s.conv_width - 1):, :].astype(jnp.dtype(cfg.compute_dtype))
    return out, {"conv": conv_tail, "ssm": h_final}


def mamba_step(
    params: Params, x: jax.Array, state: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrence.  x: (B, 1, d)."""
    B = x.shape[0]
    s = cfg.ssm
    xz = x[:, 0, :] @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                            # (B, di)

    window = jnp.concatenate(
        [state["conv"], x_in[:, None, :].astype(state["conv"].dtype)], axis=1
    )                                                              # (B, W, di)
    wf = params["conv_w"].astype(jnp.float32)
    win32 = window.astype(jnp.float32)
    W = win32.shape[1]
    acc = win32[:, 0, :] * wf[0]
    for j in range(1, W):
        acc = acc + win32[:, j, :] * wf[j]
    x_conv = (acc + params["conv_b"].astype(jnp.float32)).astype(x_in.dtype)
    x_conv = jax.nn.silu(x_conv)
    new_conv = window[:, 1:, :]

    dt, A, B_, C_ = _ssm_inputs(params, x_conv[:, None, :], cfg)
    dt, B_, C_ = dt[:, 0], B_[:, 0], C_[:, 0]                      # (B,di),(B,N)
    a = jnp.exp(dt[..., None] * A)                                 # (B,di,N)
    bx = (dt * x_conv.astype(jnp.float32))[..., None] * B_[:, None, :]
    h = a * state["ssm"] + bx
    y = jnp.einsum("bdn,bn->bd", h, C_)
    y = y + params["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "ssm": h}
