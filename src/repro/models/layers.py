"""Shared neural-net building blocks (pure JAX, dict-pytree params)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def compute_dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# --------------------------------------------------------------------------- init
def dense_init(key, shape, in_axis: int = -2, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norm
def _rms_norm_f32(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_norm_bf16_bwd(x, scale, eps):
    return _rms_norm_f32(x, scale, eps)


def _rnb_fwd(x, scale, eps):
    return _rms_norm_f32(x, scale, eps), (x, scale)


def _rnb_bwd(eps, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda a, s: _rms_norm_f32(a, s, eps), x, scale)
    dx, dscale = vjp(g)
    # bf16 boundary cotangent => backward TP collectives run at bf16 (§Perf H3)
    return dx.astype(x.dtype), dscale


_rms_norm_bf16_bwd.defvjp(_rnb_fwd, _rnb_bwd)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in fp32 (optionally with bf16 backward boundary, see perf.py)."""
    from repro.models.perf import FLAGS

    if FLAGS["norm_bf16_bwd"]:
        return _rms_norm_bf16_bwd(x, scale, eps)
    return _rms_norm_f32(x, scale, eps)


def init_rms_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


# --------------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs          # (..., S, hd/2)
    angles = angles[..., None, :]                                      # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- mlp
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, f), dtype=dt),
        "wo": dense_init(ks[1], (f, d), dtype=dt),
    }
    if cfg.mlp_glu:
        p["wg"] = dense_init(ks[2], (d, f), dtype=dt)
    return p


def mlp(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    h = x @ params["wi"]
    if cfg.mlp_glu:
        h = act(x @ params["wg"]) * h
    else:
        h = act(h)
    return h @ params["wo"]


# --------------------------------------------------------------------------- embed
def init_embedding(key, cfg: ModelConfig) -> Params:
    return {"table": embed_init(key, (cfg.vocab_size, cfg.d_model), dtype_of(cfg))}


def embed(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Project to vocab logits (fp32); applies gemma-style final softcap."""
    logits = jnp.einsum(
        "...d,vd->...v", x, params["table"], preferred_element_type=jnp.float32
    )
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap
