"""Sort-based capacity MoE (Megablocks-style dispatch, no ragged ops).

Tokens are routed top-k, sorted by expert, packed into a fixed-capacity
(E, C, d) buffer (overflow dropped, standard capacity-factor semantics), run
through batched expert FFNs, and scattered back with gate weights.  Under
GSPMD the (E, C, d) buffer resharding is what becomes the expert-parallel
all-to-all on the mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]

DEFAULT_CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: Params = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "wi": dense_init(ks[1], (E, d, f), in_axis=1, dtype=dt),
        "wo": dense_init(ks[2], (E, f, d), in_axis=1, dtype=dt),
    }
    if cfg.mlp_glu:
        p["wg"] = dense_init(ks[3], (E, d, f), in_axis=1, dtype=dt)
    if m.num_shared_experts:
        fs = m.num_shared_experts * f
        p["shared_wi"] = dense_init(ks[4], (d, fs), dtype=dt)
        p["shared_wo"] = dense_init(ks[5], (fs, d), dtype=dt)
        if cfg.mlp_glu:
            p["shared_wg"] = dense_init(ks[3], (d, fs), dtype=dt)
    return p


def _expert_capacity(tokens: int, cfg: ModelConfig, capacity_factor: float) -> int:
    m = cfg.moe
    if capacity_factor <= 0:            # exact dispatch: no dropping possible
        return tokens * m.num_experts_per_tok
    c = int(tokens * m.num_experts_per_tok * capacity_factor / m.num_experts)
    c = max(8, -(-c // 8) * 8)          # round up to a multiple of 8
    return min(c, tokens)


def moe_ffn(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    capacity_factor: float = None,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    With the ``moe_local_dispatch`` perf flag, routing/sort/scatter run
    per-data-shard inside ``shard_map`` (model axes stay auto/GSPMD): the
    token sort never becomes a global distributed sort, which is the
    dominant collective in the GSPMD-naive dispatch (§Perf, moonshot cell).
    """
    from repro.models.perf import FLAGS

    if FLAGS.get("moe_local_dispatch") and FLAGS["mesh"] is not None:
        return _moe_ffn_local(params, x, cfg, capacity_factor)
    return _moe_ffn_dense(params, x, cfg, capacity_factor)


def _moe_ffn_local(params, x, cfg, capacity_factor):
    """GShard-style grouped dispatch: split tokens into data-shard-aligned
    groups and vmap the sort/scatter over groups.  Each group's argsort,
    position-arithmetic and capacity buffer stay shard-local under GSPMD —
    the routing step never becomes a global distributed sort (§Perf H10)."""
    import numpy as np

    from repro.models.perf import FLAGS, constraint

    mesh = FLAGS["mesh"]
    ba = tuple(FLAGS["batch_axes"])
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    G = int(np.prod([sizes.get(a, 1) for a in ba]))
    B, S, d = x.shape
    T = B * S
    if G <= 1 or T % G or B % G:
        return _moe_ffn_dense(params, x, cfg, capacity_factor)

    xg = x.reshape(G, B // G * S, d)
    xg = constraint((ba, None, None))(xg)

    def one_group(xl):
        y, aux = _moe_ffn_dense(params, xl[None], cfg, capacity_factor)
        return y[0], aux

    yg, aux = jax.vmap(one_group)(xg)
    yg = constraint((ba, None, None))(yg)
    return yg.reshape(B, S, d), jnp.mean(aux)


def _moe_ffn_dense(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    capacity_factor: float = None,
) -> Tuple[jax.Array, jax.Array]:
    m = cfg.moe
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    B, S, d = x.shape
    E, K = m.num_experts, m.num_experts_per_tok
    T = B * S
    xf = x.reshape(T, d)
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu

    # ---- routing (fp32) ----------------------------------------------------
    logits = xf.astype(jnp.float32) @ params["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balancing auxiliary loss (Switch-style) ------------------------
    me = jnp.mean(probs, axis=0)                                  # (E,)
    assign = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=0)
    aux = m.aux_loss_coef * E * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------------
    A = T * K
    flat_eid = expert_idx.reshape(A)
    flat_gate = gate_vals.reshape(A)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_eid)                                 # stable
    s_eid, s_gate, s_tok = flat_eid[order], flat_gate[order], flat_tok[order]
    group_start = jnp.searchsorted(s_eid, jnp.arange(E))
    pos_in_expert = jnp.arange(A) - group_start[s_eid]

    C = _expert_capacity(T, cfg, capacity_factor)
    keep = pos_in_expert < C

    buf = jnp.zeros((E, C, d), x.dtype)
    vals = jnp.where(keep[:, None], xf[s_tok], 0)
    buf = buf.at[s_eid, jnp.where(keep, pos_in_expert, C)].set(vals, mode="drop")

    from repro.models.perf import FLAGS, constraint
    if FLAGS["moe_ep"] and FLAGS["mesh"] is not None:
        # expert-parallel dispatch: resharding the capacity buffer onto the
        # model axis makes GSPMD emit an all-to-all instead of replicating
        # the buffer (§Perf H4)
        buf = constraint(("model", None, None))(buf)

    # ---- batched expert FFN --------------------------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    if cfg.mlp_glu:
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["wg"])) * h
    else:
        h = act(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"])

    # ---- combine -------------------------------------------------------------
    gathered = out_buf[s_eid, jnp.clip(pos_in_expert, 0, C - 1)]
    gathered = gathered * (s_gate * keep).astype(gathered.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[s_tok].add(gathered)

    # ---- shared experts (always-on, Moonlight/DeepSeek style) ----------------
    if "shared_wi" in params:
        hs = xf @ params["shared_wi"]
        if cfg.mlp_glu:
            hs = act(xf @ params["shared_wg"]) * hs
        else:
            hs = act(hs)
        y = y + hs @ params["shared_wo"]

    return y.reshape(B, S, d), aux
