"""Architecture registry: ``--arch <id>`` resolution + the 40-cell matrix."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ALL_SHAPES,
    Cell,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    SMOKE_DECODE_SHAPE,
    SMOKE_SHAPE,
    reduced,
)

from repro.configs.qwen1_5_0_5b import CONFIG as _qwen
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.gemma2_2b import CONFIG as _gemma2
from repro.configs.llama3_405b import CONFIG as _llama3
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _qwen,
        _starcoder2,
        _gemma2,
        _llama3,
        _seamless,
        _falcon_mamba,
        _moonshot,
        _mixtral,
        _chameleon,
        _jamba,
    )
}

# Archs whose long-context story is sub-quadratic (SSM / hybrid / SWA rolling
# cache).  All others skip ``long_500k`` per the assignment and DESIGN.md §4.
LONG_CONTEXT_ARCHS = frozenset(
    {"falcon-mamba-7b", "jamba-v0.1-52b", "mixtral-8x7b"}
)


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_runnable(arch: str, shape: str) -> Cell:
    """Classify one cell of the 40-cell matrix."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return Cell(
            arch,
            shape,
            runnable=False,
            skip_reason="pure full-attention arch; 500k decode needs "
            "sub-quadratic attention (DESIGN.md section 4)",
        )
    return Cell(arch, shape, runnable=True)


def all_cells() -> List[Cell]:
    return [cell_runnable(a, s.name) for a in sorted(ARCHS) for s in ALL_SHAPES]


__all__ = [
    "ARCHS",
    "ALL_SHAPES",
    "Cell",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "SMOKE_DECODE_SHAPE",
    "SMOKE_SHAPE",
    "all_cells",
    "cell_runnable",
    "get_config",
    "get_shape",
    "reduced",
]
