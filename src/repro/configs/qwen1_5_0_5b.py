"""qwen1.5-0.5b — dense decoder LM. [hf:Qwen/Qwen1.5-0.5B; hf]

24L d_model=1024 16H (GQA kv=16 i.e. MHA) d_ff=2816 vocab=151936, QKV bias,
RoPE, SwiGLU, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    mlp_glu=True,
    activation="silu",
    tie_embeddings=True,
)
