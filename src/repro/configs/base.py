"""Config dataclasses for architectures, shapes and (arch x shape) cells.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  A ``Cell`` is one (arch x shape) pair of the
40-cell dry-run matrix.  Configs are plain frozen dataclasses so they can be
hashed, printed, and serialized into checkpoints / experiment logs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    d_ff_expert: int                  # per-expert hidden width
    layer_period: int = 1             # every `period`-th layer is MoE
    layer_offset: int = 0
    num_shared_experts: int = 0       # always-on experts (DeepSeek/Moonlight style)
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25     # <=0 means "no token dropping"


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16              # N in Mamba-1
    conv_width: int = 4
    expand: int = 2                   # d_inner = expand * d_model
    dt_rank: int = 0                  # 0 => ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else max(1, -(-d_model // 16))


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Fields cover the union of the 10 assigned families."""

    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                    # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int                         # dense-MLP hidden width (0 if pure-MoE/SSM)
    vocab_size: int

    head_dim: int = 0                 # 0 => d_model // num_heads
    # --- attention features -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False             # chameleon
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 => full attention
    local_global_period: int = 0      # gemma2: 2 => alternate local/global
    attn_logit_softcap: float = 0.0   # 0 => disabled
    final_logit_softcap: float = 0.0
    # --- MLP ----------------------------------------------------------------
    mlp_glu: bool = True              # gated (SwiGLU/GeGLU) vs plain 2-matmul MLP
    activation: str = "silu"          # silu | gelu
    # --- mixture of experts ---------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- state space --------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    attn_layer_period: int = 0        # hybrid: 1 attention layer per N (jamba: 8)
    attn_layer_offset: int = 0
    # --- encoder/decoder ------------------------------------------------------
    encoder_layers: int = 0           # >0 => encoder-decoder
    # --- embeddings / norms ---------------------------------------------------
    tie_embeddings: bool = False
    scale_embeddings: bool = False    # gemma: * sqrt(d_model)
    post_block_norm: bool = False     # gemma2 uses pre+post norms
    norm_eps: float = 1e-6
    # --- modality frontend (stubbed per instructions) -------------------------
    frontend: str = ""                # "" | "audio" | "vision-vq"
    # --- numerics -------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid interleave: which layer indices carry attention."""
        if self.attention_free:
            return False
        if self.attn_layer_period <= 0:
            return True
        return (i % self.attn_layer_period) == self.attn_layer_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.layer_period) == self.moe.layer_offset

    def is_local_layer(self, i: int) -> bool:
        """gemma2-style local/global alternation; local layers use the window."""
        if self.local_global_period <= 0:
            return self.sliding_window > 0
        return (i % self.local_global_period) == 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d                                    # embedding
        if not self.tie_embeddings:
            total += v * d                               # lm head
        enc_total = self.encoder_layers
        for i in range(self.num_layers + enc_total):
            is_enc = i >= self.num_layers
            li = i if not is_enc else i - self.num_layers
            # attention
            if self.is_attn_layer(li) or is_enc:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * hd
                if is_enc is False and self.is_encoder_decoder:
                    total += q + kv + o                  # cross attention
            elif self.ssm is not None:                   # mamba block
                di = self.ssm.expand * d
                dt = self.ssm.resolved_dt_rank(d)
                n = self.ssm.state_size
                total += d * 2 * di                      # in_proj
                total += di * self.ssm.conv_width + di   # conv1d
                total += di * (dt + 2 * n)               # x_proj
                total += dt * di + di                    # dt_proj
                total += di * n + di                     # A_log, D
                total += di * d                          # out_proj
            # mlp / moe
            if self.is_moe_layer(li) and not is_enc:
                m = self.moe
                mult = 3 if self.mlp_glu else 2
                total += m.num_experts * mult * d * m.d_ff_expert
                total += d * m.num_experts               # router
                total += m.num_shared_experts * mult * d * m.d_ff_expert
            elif self.d_ff > 0:
                mult = 3 if self.mlp_glu else 2
                total += mult * d * self.d_ff
            # norms (negligible, included for completeness)
            total += 2 * d
        total += d                                       # final norm
        return total


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class Cell:
    """One (architecture x shape) pair of the dry-run matrix."""

    arch: str
    shape: str
    runnable: bool = True              # False => documented skip (long_500k on full-attn)
    skip_reason: str = ""


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests.

    Preserves the structural features (GQA, MoE, SSM interleave, enc-dec,
    local/global alternation, softcaps) while shrinking every dimension.
    """
    changes = dict(
        d_model=128,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_heads else 0,
        head_dim=32 if cfg.num_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=64 if cfg.sliding_window else 0,
        name=cfg.name + "-smoke",
    )
    # keep one full interleave block, but no more
    if cfg.attn_layer_period > 0:
        changes["attn_layer_period"] = 4
        changes["attn_layer_offset"] = min(cfg.attn_layer_offset, 3)
        changes["num_layers"] = 4
    elif cfg.local_global_period > 0:
        changes["num_layers"] = 4
    else:
        changes["num_layers"] = 2
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            num_experts_per_tok=min(cfg.moe.num_experts_per_tok, 2),
            d_ff_expert=64,
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, state_size=8, dt_rank=8)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)


SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
SMOKE_DECODE_SHAPE = ShapeConfig("smoke_decode", 128, 2, "decode")
