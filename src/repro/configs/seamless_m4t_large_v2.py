"""seamless-m4t-large-v2 — encoder-decoder speech/text backbone. [arXiv:2308.11596; hf]

24L (enc) + 24L (dec), d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Per instructions the audio frontend (w2v-BERT conformer feature extractor) is
a STUB: ``input_specs()`` supplies precomputed frame embeddings of shape
(batch, frames, d_model); the backbone here is the transformer enc-dec with
cross-attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    rope_theta=10_000.0,
    mlp_glu=False,
    activation="gelu",
    frontend="audio",
)
