"""llama3-405b — frontier-scale dense decoder LM. [arXiv:2407.21783; unverified]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256, RoPE theta 500k,
SwiGLU.  FSDP + zero-1 optimizer sharding are mandatory at this size.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    mlp_glu=True,
    activation="silu",
)
