"""mixtral-8x7b — sparse MoE LM with sliding-window attention. [arXiv:2401.04088; hf]

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=14336 vocab=32000,
MoE 8 experts top-2, SWA window 4096 (rolling KV cache => sub-quadratic
long-context decode, so ``long_500k`` runs with an O(window) cache).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=32_000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2, d_ff_expert=14_336),
    mlp_glu=True,
    activation="silu",
)
