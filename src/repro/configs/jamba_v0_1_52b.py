"""jamba-v0.1-52b — hybrid Mamba + attention + MoE. [arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Attention:Mamba
interleave 1:7 (one attention layer per 8, at offset 4), MoE 16 experts top-2
on every other layer (offset 1).  Hybrid => ``long_500k`` runs (attention
layers are 4/32; decode state dominated by Mamba states + 4 KV caches).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=65_536,
    moe=MoEConfig(
        num_experts=16,
        num_experts_per_tok=2,
        d_ff_expert=14_336,
        layer_period=2,
        layer_offset=1,
    ),
    ssm=SSMConfig(state_size=16, conv_width=4, expand=2),
    attn_layer_period=8,
    attn_layer_offset=4,
    mlp_glu=True,
    activation="silu",
)
