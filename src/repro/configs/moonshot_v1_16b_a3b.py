"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — fine-grained MoE LM.
[hf:moonshotai/Moonlight-16B-A3B; hf]

48L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=163840, MoE 64
experts top-6 (DeepSeek-V3-style fine-grained experts; the released model
additionally uses shared experts + a dense first layer — we include 2 shared
experts to match the "a3b" active-parameter budget and note the adaptation in
DESIGN.md).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    vocab_size=163_840,
    rope_theta=50_000.0,
    moe=MoEConfig(
        num_experts=64,
        num_experts_per_tok=6,
        d_ff_expert=1408,
        num_shared_experts=2,
    ),
    mlp_glu=True,
    activation="silu",
)
