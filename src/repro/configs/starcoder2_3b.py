"""starcoder2-3b — dense decoder LM, extreme GQA. [arXiv:2402.19173; hf]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, RoPE, plain GELU MLP
(4x expansion, non-gated), attention + MLP biases per the released config.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12_288,
    vocab_size=49_152,
    qkv_bias=True,
    rope_theta=100_000.0,
    mlp_glu=False,
    activation="gelu",
    tie_embeddings=True,
)
