"""falcon-mamba-7b — attention-free Mamba-1 LM. [arXiv:2410.05355; unverified]

64L d_model=4096, ssm_state=16, conv=4, expand=2 (d_inner=8192),
dt_rank=256, vocab=65024.  No KV cache: decode state is O(d_inner * N) per
layer, so ``long_500k`` runs natively.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65_024,
    ssm=SSMConfig(state_size=16, conv_width=4, expand=2, dt_rank=256),
    tie_embeddings=True,
)
