"""chameleon-34b — early-fusion VLM decoder. [arXiv:2405.09818; unverified]

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + VQ image
tokens in one vocabulary).  QK-norm as in the paper.  Early fusion means the
modality frontend is the VQ-VAE tokenizer, which is a STUB here — inputs are
already token ids drawn from the unified vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22_016,
    vocab_size=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    mlp_glu=True,
    activation="silu",
    frontend="vision-vq",
)
