from repro.optim.adamw import OptConfig, apply_updates, init_opt_state, lr_at

__all__ = ["OptConfig", "apply_updates", "init_opt_state", "lr_at"]
