"""AdamW with fp32 master weights — built for zero-1 sharded state.

State layout: ``{"step", "m", "v", "master"}`` where m/v/master mirror the
param tree in fp32.  The launch layer assigns these leaves zero-1 shardings
(sharded over data *and* model) so 405B-class optimizer state distributes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init_opt_state(params: PyTree) -> Dict[str, PyTree]:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda x: x.astype(jnp.float32), params),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _decay_mask(path: str) -> bool:
    """Apply weight decay to matrices only (skip norms / biases / scalars)."""
    for tag in ("scale", "bias", "'bq'", "'bk'", "'bv'", "conv_b", "dt_bias", "'D'"):
        if tag in path:
            return False
    return True


def apply_updates(
    params: PyTree,
    grads: PyTree,
    state: Dict[str, PyTree],
    cfg: OptConfig,
) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat_p]
    leaves_p = [x for _, x in flat_p]
    leaves_g = jax.tree.leaves(grads)
    leaves_m = jax.tree.leaves(state["m"])
    leaves_v = jax.tree.leaves(state["v"])
    leaves_w = jax.tree.leaves(state["master"])

    new_p, new_m, new_v, new_w = [], [], [], []
    for path, p, g, m, v, w in zip(paths, leaves_p, leaves_g, leaves_m, leaves_v, leaves_w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            upd = upd + cfg.weight_decay * w
        w = w - lr * upd
        new_p.append(w.astype(p.dtype))
        new_m.append(m)
        new_v.append(v)
        new_w.append(w)

    unf = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    new_state = {"step": step, "m": unf(new_m), "v": unf(new_v), "master": unf(new_w)}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return unf(new_p), new_state, metrics
