"""Pallas kernel package."""
