"""Public wrapper for the selective-scan kernel (matches mamba.selective_scan)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import kernel as K


def selective_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,            # (D, N), negative (A = -exp(A_log))
    B_: jax.Array,
    C_: jax.Array,
    h0: jax.Array,
    *,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Drop-in for ``repro.models.mamba.selective_scan`` (kernel path).

    The kernel consumes ``a_log`` with A = -exp(a_log); the model stores
    ``A_log`` with A = -exp(A_log) as well, so we invert the caller's A here.
    """
    a_log = jnp.log(-A.astype(jnp.float32))
    return K.selective_scan_pallas(
        x.astype(jnp.float32),
        dt.astype(jnp.float32),
        a_log,
        B_.astype(jnp.float32),
        C_.astype(jnp.float32),
        h0.astype(jnp.float32),
        interpret=interpret,
    )
