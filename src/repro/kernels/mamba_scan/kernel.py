"""Chunked selective-scan Pallas kernel (Mamba-1, TPU target).

Grid = (B, D/bd, S/bs) with the sequence dimension innermost and sequential;
the (bd, N) fp32 state lives in VMEM scratch across sequence chunks, so HBM
traffic is exactly one read of (x, dt, B, C) and one write of y — the
recurrence never round-trips the state, which is the whole point of the
hardware-aware scan (the paper-for-this-kernel's GPU analogue keeps state in
SRAM/registers; VMEM scratch is the TPU analogue).

Within a chunk the recurrence is a ``fori_loop`` over time steps operating on
(bd, N) tiles — vectorised across the channel block and the (small, =16)
state dimension, sequential in t, which matches the VPU's preference for
long-lane elementwise work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_log_ref, b_ref, c_ref, h0_ref,
                 y_ref, hout_ref, h_ref, *, bs: int, num_chunks: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    A = -jnp.exp(a_log_ref[...].astype(jnp.float32))          # (bd, N)

    def step(t, _):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)            # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)              # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt_t[:, None] * A)                        # (bd, N)
        bx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = a * h_ref[...] + bx
        h_ref[...] = h
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bs, step, 0)

    @pl.when(si == num_chunks - 1)
    def _finish():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("bs", "bd", "interpret"))
def selective_scan_pallas(
    x: jax.Array,      # (B, S, D) fp32
    dt: jax.Array,     # (B, S, D) fp32
    a_log: jax.Array,  # (D, N): A = -exp(a_log)
    b: jax.Array,      # (B, S, N)
    c: jax.Array,      # (B, S, N)
    h0: jax.Array,     # (B, D, N)
    *,
    bs: int = 64,
    bd: int = 256,
    interpret: bool = True,
):
    B, S, D = x.shape
    N = a_log.shape[1]
    bs = min(bs, S)
    bd = min(bd, D)
    assert S % bs == 0 and D % bd == 0, (S, bs, D, bd)
    num_chunks = S // bs

    kernel = functools.partial(_scan_kernel, bs=bs, num_chunks=num_chunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, D // bd, num_chunks),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, bs, bd), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((bd, N), lambda b_, di, si: (di, 0)),
            pl.BlockSpec((1, bs, N), lambda b_, di, si: (b_, si, 0)),
            pl.BlockSpec((1, bs, N), lambda b_, di, si: (b_, si, 0)),
            pl.BlockSpec((1, bd, N), lambda b_, di, si: (b_, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b_, di, si: (b_, si, di)),
            pl.BlockSpec((1, bd, N), lambda b_, di, si: (b_, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c, h0)
    return y, h_final
