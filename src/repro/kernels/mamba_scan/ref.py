"""Pure-jnp oracle for the chunked selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, B_, C_, h0):
    """Sequential reference.  x, dt: (B,S,D); A: (D,N); B_, C_: (B,S,N);
    h0: (B,D,N).  Returns (y (B,S,D), h_final (B,D,N)) in fp32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    a_all = jnp.exp(dt[..., None] * A)                        # (B,S,D,N)
    bx_all = (dt * x)[..., None] * B_[:, :, None, :].astype(jnp.float32)

    def step(h, inp):
        a, bx, c = inp
        h = a * h + bx
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    h, ys = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (a_all.swapaxes(0, 1), bx_all.swapaxes(0, 1),
         C_.astype(jnp.float32).swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), h
