"""Flash-attention forward Pallas kernel (TPU target, interpret-validated).

Online-softmax blocked attention.  Grid = (B, H, num_q_blocks, num_kv_blocks)
with the kv dimension innermost and marked "arbitrary" (sequential) so the
(bq, hd) fp32 accumulator + (bq,) running max / denominator live in VMEM
scratch across kv steps.  BlockSpecs tile HBM->VMEM as:

    q:  (1, 1, bq, hd)    per (b, h, qi)   — revisited for every kv step
    k/v:(1, 1, bk, hd)    per (b, h//G, ki) — GQA folds kv-head indexing into
                                              the index_map (no materialised
                                              head broadcast in HBM)

MXU alignment: bq/bk default 128 (the MXU systolic dimension), hd is padded
by the wrapper to a multiple of 128 when needed.  Causal + sliding-window
masking and gemma-style logit soft-capping are fused into the kv loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, bq: int, bk: int, num_kv: int, causal: bool, window: int,
    softcap: float, scale: float, q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                    # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard fully-masked rows: keep exp() finite
    m_safe = jnp.where(m_cur == NEG_INF, 0.0, m_cur)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ki == num_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "bq", "bk", "q_offset", "interpret",
    ),
)
def flash_attention_fwd(
    q: jax.Array,                 # (B, H, Sq, hd)
    k: jax.Array,                 # (B, Hk, Sk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    bq: int = 128,
    bk: int = 128,
    q_offset: int = 0,
    interpret: bool = True,       # CPU container: interpret; TPU: False
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Hk, Sk = k.shape[1], k.shape[2]
    G = H // Hk
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    num_q, num_kv = Sq // bq, Sk // bk
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel,
        bq=bq, bk=bk, num_kv=num_kv, causal=causal, window=window,
        softcap=softcap, scale=scale, q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, num_q, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
