"""Pure-jnp oracle for the flash-attention kernel (the ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jax.Array,                # (B, H, Sq, hd)
    k: jax.Array,                # (B, Hk, Sk, hd)
    v: jax.Array,                # (B, Hk, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,             # 0 = full; else sliding window width
    softcap: float = 0.0,
    q_offset: int = 0,           # absolute position of q[0] (decode/prefill)
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Hk = k.shape[1]
    Sk = k.shape[2]
    G = H // Hk
    qf = q.astype(jnp.float32) / np.sqrt(hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hk, G, Sq, hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
