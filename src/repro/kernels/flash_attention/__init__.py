"""Pallas kernel package."""
