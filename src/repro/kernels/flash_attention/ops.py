"""Jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, hd) (as produced by
``repro.models.attention``), handles layout transposition, head-dim padding
to the 128-lane MXU width, and provides a ``jax.custom_vjp`` whose backward
pass recomputes attention through the pure-jnp reference (flash backward
kernel is future work; the recompute keeps training correct with the fused
forward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref as R


def _pad_hd(x, hd_pad):
    if hd_pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, hd_pad)))


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6)
)
def _flash(q, k, v, causal, window, softcap, interpret):
    # layout: (B, S, H, hd) -> (B, H, S, hd)
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    hd = qt.shape[-1]
    pad = (-hd) % 128
    if pad:
        # kernel scales by 1/sqrt(hd+pad); pre-scale q to net 1/sqrt(hd)
        qt = _pad_hd(qt * (((hd + pad) / hd) ** 0.5), pad)
        kt = _pad_hd(kt, pad)
        vt = _pad_hd(vt, pad)
    out = K.flash_attention_fwd(
        qt, kt, vt,
        causal=causal,
        window=window,
        softcap=softcap,
        interpret=interpret,
    )
    if pad:
        out = out[..., :hd]
    return out.swapaxes(1, 2)


def _flash_fwd(q, k, v, causal, window, softcap, interpret):
    return _flash(q, k, v, causal, window, softcap, interpret), (q, k, v)


def _flash_bwd(causal, window, softcap, interpret, res, g):
    q, k, v = res

    def ref_fn(q, k, v):
        out = R.attention_ref(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=causal, window=window, softcap=softcap,
        )
        return out.swapaxes(1, 2)

    _, vjp = jax.vjp(ref_fn, q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,               # (B, S, H, hd)
    k: jax.Array,               # (B, S, Hk, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Fused attention; returns (B, S, H, hd)."""
    return _flash(q, k, v, causal, window, softcap, interpret)
