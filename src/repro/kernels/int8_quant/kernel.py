"""Blockwise int8 quantise / dequantise Pallas kernels.

Used by the compressed gradient all-reduce (repro.core.compression): the
quantise step runs once per ring hop, so it is a bandwidth-critical
elementwise kernel.  Tiles of (rows, 128) live in VMEM; the per-row absmax
reduction and the scaled round happen in one pass (single HBM read, two small
writes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rows, 128)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0, 1e-12)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def quantize_pallas(x: jax.Array, *, rows: int = 256, interpret: bool = True):
    """x: 1-D, length divisible by 128."""
    n = x.size // QBLOCK
    rows = min(rows, n)
    if n % rows:
        rows = n
    xb = x.reshape(n, QBLOCK)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n // rows,),
        in_specs=[pl.BlockSpec((rows, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return q, s


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def dequantize_pallas(q: jax.Array, s: jax.Array, *, rows: int = 256,
                      interpret: bool = True) -> jax.Array:
    n = q.shape[0]
    rows = min(rows, n)
    if n % rows:
        rows = n
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(n // rows,),
        in_specs=[
            pl.BlockSpec((rows, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, QBLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
    return out.reshape(-1)
