"""Public wrappers for the int8 quantisation kernels."""
from repro.kernels.int8_quant.kernel import dequantize_pallas, quantize_pallas

quantize = quantize_pallas
dequantize = dequantize_pallas

__all__ = ["quantize", "dequantize", "quantize_pallas", "dequantize_pallas"]
