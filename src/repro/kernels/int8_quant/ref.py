"""Pure-jnp oracle for blockwise int8 quantisation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x: jax.Array, block: int = 128):
    xb = x.reshape(-1, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(-1)
