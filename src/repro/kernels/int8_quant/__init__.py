"""Pallas kernel package."""
