from repro.train.trainer import TrainConfig, Trainer, make_mesh

__all__ = ["TrainConfig", "Trainer", "make_mesh"]
