"""Train-step factories: GSPMD-native and explicit-strategy (paper) modes.

``make_train_step``      — pjit end-to-end; XLA inserts the gradient
                           collectives (reduce-scatter/all-reduce over data,
                           all-to-all for MoE).  This is the TPU baseline —
                           the fabric's "in-network aggregation".
``make_explicit_train_step`` — per-shard gradients via ``shard_map`` over the
                           data/pod axes, then one of the paper's mechanisms
                           (ring / butterfly / PS / hierarchical /
                           compressed) from ``repro.core`` synchronises them.
                           This is how the paper's subject is a first-class
                           runtime feature rather than a simulator-only idea.

Both support microbatch gradient accumulation: batches arrive with a leading
``(accum, micro, ...)`` layout (see data pipeline / input_specs) and the step
scans over the accum dim, accumulating fp32 grads.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.api import GradSync, GradSyncConfig
from repro.models import model as M
from repro.optim import OptConfig, apply_updates

PyTree = Any


def _loss_fn(cfg: ModelConfig, use_flash: bool):
    def loss(params, batch):
        l, metrics = M.loss_fn(params, batch, cfg, use_flash=use_flash)
        return l, metrics

    return loss


def _grads_of(cfg: ModelConfig, use_flash: bool, grad_accum: int):
    """Returns fn(params, batch) -> (grads, metrics).

    Gradient dtype: fp32 by default; bf16 when the ``bf16_grad_accum`` perf
    flag is set (halves gradient-sync wire bytes; the fp32 master weights in
    the optimizer keep update math exact).
    """
    from repro.models.perf import FLAGS

    loss = _loss_fn(cfg, use_flash)
    vg = jax.value_and_grad(loss, has_aux=True)
    accum_dtype = jnp.bfloat16 if FLAGS["bf16_grad_accum"] else jnp.float32

    if grad_accum <= 1:
        def fn(params, batch):
            (_, metrics), grads = vg(params, batch)
            return jax.tree.map(lambda g: g.astype(accum_dtype), grads), metrics
        return fn

    def fn(params, batch):
        def micro(carry, mb):
            acc, _ = carry
            (_, metrics), grads = vg(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(accum_dtype) / grad_accum, acc, grads
            )
            return (acc, metrics), None

        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, accum_dtype), params)
        metrics0_shape = jax.eval_shape(
            lambda p, b: vg(p, b)[0][1], params, jax.tree.map(lambda x: x[0], batch)
        )
        metrics0 = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), metrics0_shape)
        (grads, metrics), _ = jax.lax.scan(micro, (zeros, metrics0), batch)
        return grads, metrics

    return fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    *,
    grad_accum: int = 1,
    use_flash: bool = False,
    grad_shardings: Optional[PyTree] = None,
) -> Callable:
    """GSPMD-native step (jit with shardings applied by the caller).

    ``grad_shardings``: when set (perf flag ``grad_zero1``), gradients are
    constrained to the zero-1 data-sharded layout, turning the gradient sync
    into a reduce-scatter that matches the sharded optimizer state.
    """
    grads_of = _grads_of(cfg, use_flash, grad_accum)

    def step(params, opt_state, batch):
        grads, metrics = grads_of(params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step


def make_explicit_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    mesh,
    sync_cfg: GradSyncConfig,
    params_shape: PyTree,
    *,
    grad_accum: int = 1,
    use_flash: bool = False,
) -> Tuple[Callable, GradSync]:
    """Paper-strategy step: per-shard grads -> explicit collective -> update.

    Params replicated over the sync axes (pure DP + optional pod axis);
    model-parallel sharding composes only with the gspmd step.
    """
    grads_of = _grads_of(cfg, use_flash, grad_accum)
    sync = GradSync(sync_cfg, params_shape)
    axes = (sync_cfg.axis_name,) + ((sync_cfg.pod_axis,) if sync_cfg.pod_axis else ())
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def per_shard(params, batch):
        grads, metrics = grads_of(params, batch)
        # strategy averages over the sync axes
        reduced, _ = sync(grads, axis_sizes)
        metrics = jax.tree.map(
            lambda x: jax.lax.pmean(x, axes[0]) if x.ndim == 0 else x, metrics
        )
        return reduced, metrics

    batch_spec = P(axes if grad_accum <= 1 else None)
    micro_spec = P(*(((None,) + (axes,)) if grad_accum > 1 else (axes,)))

    smap = jax.shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(), micro_spec),
        out_specs=(P(), P()),
        check_vma=False,
    )

    def step(params, opt_state, batch):
        grads, metrics = smap(params, batch)
        params, opt_state, om = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **om}

    return step, sync
