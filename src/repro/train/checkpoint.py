"""Checkpointing: atomic, keep-k, async, elastic-reshard on restore.

Format: one directory per step (``step_000123/``) containing a single
uncompressed ``arrays.npz`` (leaves keyed by pytree path) plus
``manifest.json`` (step, leaf index, framework metadata).  Writes land in a
``.tmp-*`` sibling and are ``os.replace``d into place, so a preempted writer
never leaves a half-readable checkpoint; ``latest_step`` only believes
directories whose manifest exists.

Restore takes the *target* shardings (from the current mesh's ShardingPlan),
so a checkpoint taken on a 16x16 mesh restores onto 2x16x16, 8x1, or a single
CPU device unchanged — that is the elastic-rescale path.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_STEP_RE = re.compile(r"^step_(\d{9})$")


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:09d}")


def _paths_of(tree: PyTree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def save_checkpoint(
    base: str,
    step: int,
    tree: PyTree,
    *,
    keep: int = 3,
    background: bool = False,
    extra_meta: Optional[Dict] = None,
) -> Optional[threading.Thread]:
    """Snapshot ``tree`` (device arrays ok) at ``step``.

    With ``background=True``, the device->host copy happens synchronously (so
    training can mutate donated buffers) and the file write runs in a thread.
    """
    os.makedirs(base, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    host = {}
    dtypes = {}
    for kp, x in flat:
        key = jax.tree_util.keystr(kp)
        arr = np.asarray(jax.device_get(x))
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            arr = arr.view(np.uint16)   # npz can't round-trip ml_dtypes
        host[key] = arr
    meta = {
        "step": int(step),
        "leaves": list(host.keys()),
        "dtypes": dtypes,
        "framework": "repro",
        **(extra_meta or {}),
    }

    def write():
        tmp = tempfile.mkdtemp(prefix=".tmp-ckpt-", dir=base)
        try:
            np.savez(os.path.join(tmp, _ARRAYS), **{k: v for k, v in host.items()})
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(meta, f)
            final = _step_dir(base, step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        _prune(base, keep)

    if background:
        t = threading.Thread(target=write, daemon=False)
        t.start()
        return t
    write()
    return None


def _prune(base: str, keep: int) -> None:
    steps = all_steps(base)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def all_steps(base: str) -> List[int]:
    if not os.path.isdir(base):
        return []
    out = []
    for d in os.listdir(base):
        m = _STEP_RE.match(d)
        if m and os.path.exists(os.path.join(base, d, _MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(base: str) -> Optional[int]:
    steps = all_steps(base)
    return steps[-1] if steps else None


def restore_checkpoint(
    base: str,
    step: int,
    like: PyTree,
    shardings: Optional[PyTree] = None,
) -> PyTree:
    """Load ``step`` into the structure of ``like``; place per ``shardings``.

    Resharding is implicit: ``jax.device_put(host_array, target_sharding)``
    lays the full array out on whatever mesh the current job runs — the
    checkpoint is mesh-agnostic (elastic restart / pod-count change).
    """
    import ml_dtypes

    d = _step_dir(base, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        meta = json.load(f)
    dtypes = meta.get("dtypes", {})
    with np.load(os.path.join(d, _ARRAYS)) as z:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (
            jax.tree.leaves(
                shardings,
                is_leaf=lambda x: isinstance(x, jax.sharding.Sharding),
            )
            if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (kp, ref), sh in zip(flat, shard_flat):
            key = jax.tree_util.keystr(kp)
            arr = z[key]
            if dtypes.get(key) == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"checkpoint/model shape mismatch at {key}: "
                    f"{arr.shape} vs {ref.shape}"
                )
            if str(arr.dtype) != str(ref.dtype):
                arr = arr.astype(ref.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
