"""Fault-tolerance utilities: preemption handling, straggler watchdog, retry.

On a real pod-scale deployment these hook the cluster scheduler:

* ``PreemptionGuard`` — SIGTERM/SIGINT (the TPU maintenance-event signal on
  Cloud) flips a flag; the training loop checkpoints and exits cleanly at the
  next step boundary instead of dying mid-write.
* ``StepWatchdog`` — tracks per-step wall time; a step slower than
  ``factor``x the trailing median marks a *straggler event* (on hardware this
  is how you catch a flaky HBM/host — the mitigation callback would trigger
  a hot-spare swap / job reshape; here it feeds metrics + tests).
* ``retry_step`` — bounded retries around transient step failures (e.g. a
  DCN collective timeout surfacing as an XLA error) before escalating.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, List, Optional


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


class StepWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.on_straggler = on_straggler
        self.history: List[float] = []
        self.straggler_steps: List[int] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        flagged = False
        if len(self.history) >= 8:
            med = statistics.median(self.history[-self.window:])
            if dt > self.factor * med:
                flagged = True
                self.straggler_steps.append(step)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self.history.append(dt)
        return flagged

    @property
    def median(self) -> float:
        return statistics.median(self.history) if self.history else 0.0


def retry_step(fn: Callable, *args, retries: int = 2, backoff: float = 0.5):
    """Run ``fn(*args)``, retrying transient failures."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except (RuntimeError, OSError) as e:           # XLA/collective errors
            last = e
            if attempt == retries:
                raise
            time.sleep(backoff * (2 ** attempt))
    raise last  # unreachable
