"""Training loop: mesh + shardings + steps + checkpoints + fault tolerance."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, reduced
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.api import GradSyncConfig
from repro.data import DataConfig, make_pipeline
from repro.models import model as M
from repro.optim import OptConfig, init_opt_state
from repro.launch.shardings import ShardingPlan
from repro.train import checkpoint as ckpt
from repro.train import steps as steps_lib
from repro.train.fault_tolerance import PreemptionGuard, StepWatchdog, retry_step

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen1.5-0.5b"
    shape: str = "train_4k"
    smoke: bool = True                  # reduced config + tiny shape (CPU)
    steps: int = 20
    mesh_shape: tuple = ()              # () => all local devices on 'data'
    strategy: str = "gspmd"             # gspmd | ring | butterfly | ps | ...
    compression: str = ""               # "" | int8 | topk
    grad_accum: int = 1
    use_flash: bool = False
    seed: int = 0
    ckpt_dir: str = ""
    ckpt_every: int = 0
    keep_ckpts: int = 3
    log_every: int = 10
    batch_override: int = 0
    seq_override: int = 0
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    data: DataConfig = dataclasses.field(default_factory=DataConfig)


def make_mesh(shape: tuple) -> Mesh:
    n = len(jax.devices())
    if not shape:
        return jax.make_mesh((n,), ("data",))
    names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(shape)]
    return jax.make_mesh(shape, names)


class Trainer:
    def __init__(self, tcfg: TrainConfig):
        self.tcfg = tcfg
        mcfg = get_config(tcfg.arch)
        shape = get_shape(tcfg.shape) if tcfg.shape in (
            "train_4k", "prefill_32k", "decode_32k", "long_500k"
        ) else None
        if tcfg.smoke:
            mcfg = reduced(mcfg)
            shape = ShapeConfig("smoke", tcfg.seq_override or 128,
                                tcfg.batch_override or 8, "train")
        if tcfg.batch_override or tcfg.seq_override:
            shape = dataclasses.replace(
                shape,
                global_batch=tcfg.batch_override or shape.global_batch,
                seq_len=tcfg.seq_override or shape.seq_len,
            )
        self.mcfg, self.shape = mcfg, shape
        self.mesh = make_mesh(tcfg.mesh_shape)
        self.plan = ShardingPlan(mcfg, self.mesh)
        self.pipeline = make_pipeline(tcfg.data, mcfg, shape)
        self.watchdog = StepWatchdog()
        self.step = 0
        self._build()

    # ------------------------------------------------------------------ build
    def _build(self):
        tcfg, mcfg = self.tcfg, self.mcfg
        params_shape = jax.eval_shape(
            lambda k: M.init_params(k, mcfg), jax.random.PRNGKey(tcfg.seed)
        )
        self.param_sh = self.plan.param_shardings(params_shape)
        self.opt_sh = self.plan.shardings_for(
            {
                "step": P(),
                "m": self.plan.param_specs(params_shape, zero1=True),
                "v": self.plan.param_specs(params_shape, zero1=True),
                "master": self.plan.param_specs(params_shape, zero1=True),
            }
        )

        if tcfg.strategy == "gspmd":
            step_fn = steps_lib.make_train_step(
                mcfg, tcfg.opt, grad_accum=tcfg.grad_accum, use_flash=tcfg.use_flash
            )
        else:
            step_fn, self.sync = steps_lib.make_explicit_train_step(
                mcfg, tcfg.opt, self.mesh,
                GradSyncConfig(
                    strategy=tcfg.strategy,
                    compression=tcfg.compression,
                    pod_axis="pod" if "pod" in self.mesh.axis_names
                    and dict(zip(self.mesh.axis_names, self.mesh.devices.shape))["pod"] > 1
                    else "",
                ),
                params_shape,
                grad_accum=tcfg.grad_accum,
                use_flash=tcfg.use_flash,
            )

        batch_shape = self._batch_shape()
        self.batch_sh = self.plan.shardings_for(self._batch_specs(batch_shape))
        self.step_fn = jax.jit(
            step_fn,
            in_shardings=(self.param_sh, self.opt_sh, self.batch_sh),
            out_shardings=(self.param_sh, self.opt_sh, None),
            donate_argnums=(0, 1),
        )

    def _batch_specs(self, batch_shape: PyTree) -> PyTree:
        axes = self.plan.batch_axes
        ga = self.tcfg.grad_accum

        def spec(x):
            if ga > 1:
                return P(None, axes, *([None] * (x.ndim - 2)))
            return P(axes, *([None] * (x.ndim - 1)))

        return jax.tree.map(spec, batch_shape)

    def _batch_shape(self) -> PyTree:
        B, S = self.shape.global_batch, self.shape.seq_len
        ga = self.tcfg.grad_accum
        mk = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if ga > 1:
            b = {"tokens": mk(ga, B // ga, S), "labels": mk(ga, B // ga, S)}
            if self.mcfg.is_encoder_decoder:
                b["frames"] = jax.ShapeDtypeStruct(
                    (ga, B // ga, S, self.mcfg.d_model), jnp.bfloat16
                )
            return b
        b = {"tokens": mk(B, S), "labels": mk(B, S)}
        if self.mcfg.is_encoder_decoder:
            b["frames"] = jax.ShapeDtypeStruct((B, S, self.mcfg.d_model), jnp.bfloat16)
        return b

    # -------------------------------------------------------------- lifecycle
    def init_or_restore(self):
        tcfg = self.tcfg
        latest = ckpt.latest_step(tcfg.ckpt_dir + "/params") if tcfg.ckpt_dir else None
        params_shape = jax.eval_shape(
            lambda k: M.init_params(k, self.mcfg), jax.random.PRNGKey(tcfg.seed)
        )
        if latest is not None:
            like_p = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), params_shape)
            self.params = ckpt.restore_checkpoint(
                tcfg.ckpt_dir + "/params", latest, like_p, self.param_sh
            )
            opt_like = jax.eval_shape(init_opt_state, params_shape)
            opt_like = jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), opt_like)
            self.opt_state = ckpt.restore_checkpoint(
                tcfg.ckpt_dir + "/opt", latest, opt_like, self.opt_sh
            )
            self.step = latest
        else:
            init = jax.jit(
                lambda k: M.init_params(k, self.mcfg), out_shardings=self.param_sh
            )
            self.params = init(jax.random.PRNGKey(tcfg.seed))
            self.opt_state = jax.jit(
                init_opt_state, out_shardings=self.opt_sh
            )(self.params)
            self.step = 0

    def save(self, background: bool = False):
        if not self.tcfg.ckpt_dir:
            return
        ckpt.save_checkpoint(
            self.tcfg.ckpt_dir + "/params", self.step, self.params,
            keep=self.tcfg.keep_ckpts, background=background,
        )
        ckpt.save_checkpoint(
            self.tcfg.ckpt_dir + "/opt", self.step, self.opt_state,
            keep=self.tcfg.keep_ckpts, background=background,
        )

    def _device_batch(self, host_batch: Dict[str, np.ndarray]) -> PyTree:
        ga = self.tcfg.grad_accum
        out = {}
        for k, v in host_batch.items():
            if ga > 1:
                v = v.reshape((ga, v.shape[0] // ga) + v.shape[1:])
            if k == "frames":
                v = v.astype(jnp.bfloat16)
            out[k] = jax.device_put(v, self.batch_sh[k])
        return out

    # ------------------------------------------------------------------- run
    def run(self, num_steps: Optional[int] = None) -> Dict[str, float]:
        tcfg = self.tcfg
        n = num_steps or tcfg.steps
        history = []
        with PreemptionGuard() as guard:
            for _ in range(n):
                if guard.requested:
                    self.save()
                    break
                host = self.pipeline.batch_at(self.step)
                batch = self._device_batch(host)
                t0 = time.perf_counter()
                self.params, self.opt_state, metrics = retry_step(
                    self.step_fn, self.params, self.opt_state, batch
                )
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.watchdog.record(self.step, dt)
                self.step += 1
                history.append(loss)
                if tcfg.log_every and self.step % tcfg.log_every == 0:
                    print(
                        f"step {self.step:5d} loss {loss:.4f} "
                        f"lr {float(metrics['lr']):.2e} "
                        f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f} ms"
                    )
                if tcfg.ckpt_every and self.step % tcfg.ckpt_every == 0:
                    self.save(background=True)
        self.pipeline.stop()
        return {
            "first_loss": history[0] if history else float("nan"),
            "last_loss": history[-1] if history else float("nan"),
            "steps": len(history),
            "median_step_s": self.watchdog.median,
        }
