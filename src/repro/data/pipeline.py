"""Token data pipeline: deterministic synthetic stream + memmap corpus.

Design constraints from the fault-tolerance story:
  * **stateless in step** — ``batch_at(step)`` is a pure function of
    (seed, step), so a restarted/elastically-rescaled job resumes the exact
    token stream with no iterator state in the checkpoint;
  * **shardable** — each batch is produced host-locally then device_put with
    the plan's batch sharding (single host here; the slicing logic is
    per-process in ``process_slice``);
  * background prefetch thread with a bounded queue (overlaps host datagen
    with device compute).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "synthetic"            # "synthetic" | "memmap"
    seed: int = 0
    path: str = ""                     # memmap token file (uint16/uint32)
    prefetch: int = 2


def _synthetic_tokens(seed: int, step: int, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Deterministic pseudo-corpus: a per-(step) seeded Zipf-ish stream with
    local structure (n-gram repetition) so models actually learn something."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    # zipf-ish marginal over a capped vocab for learnability
    v = min(vocab, 32_768)
    raw = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = (raw - 1) % v
    # inject copy structure: second half of each row repeats the first half
    half = seq // 2
    if half > 0:
        toks[:, half:half * 2] = toks[:, :half]
    return toks.astype(np.int32)


class Pipeline:
    def __init__(self, dcfg: DataConfig, mcfg: ModelConfig, shape: ShapeConfig,
                 process_index: int = 0, process_count: int = 1):
        self.dcfg, self.mcfg, self.shape = dcfg, mcfg, shape
        self.process_index, self.process_count = process_index, process_count
        self._mm: Optional[np.ndarray] = None
        if dcfg.kind == "memmap":
            self._mm = np.memmap(dcfg.path, dtype=np.uint16, mode="r")
        self._q: "queue.Queue" = queue.Queue(maxsize=dcfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---------------------------------------------------------------- batches
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B = self.shape.global_batch // self.process_count
        S = self.shape.seq_len
        V = self.mcfg.vocab_size
        if self._mm is not None:
            n = len(self._mm)
            stride = B * self.process_count * (S + 1)
            base = (step * stride + self.process_index * B * (S + 1)) % max(n - stride, 1)
            flat = np.asarray(self._mm[base: base + B * (S + 1)], np.int32) % V
            arr = flat.reshape(B, S + 1)
            tokens, labels = arr[:, :-1], arr[:, 1:]
        else:
            toks = _synthetic_tokens(
                self.dcfg.seed + self.process_index, step, B, S + 1, V
            )
            tokens, labels = toks[:, :-1], toks[:, 1:]
        batch = {"tokens": tokens, "labels": labels.copy()}
        if self.mcfg.is_encoder_decoder:
            rng = np.random.default_rng(np.uint64(step + 17))
            batch["frames"] = rng.standard_normal(
                (B, S, self.mcfg.d_model), np.float32
            ).astype(np.float32)
        return batch

    # --------------------------------------------------------------- prefetch
    def start(self, first_step: int) -> None:
        def worker():
            step = first_step
            while not self._stop.is_set():
                b = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self) -> Any:
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)


def make_pipeline(dcfg: DataConfig, mcfg: ModelConfig, shape: ShapeConfig,
                  **kw) -> Pipeline:
    return Pipeline(dcfg, mcfg, shape, **kw)
