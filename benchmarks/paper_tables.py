"""Paper tables 1/4/6/8/9/10 reproduced on the trace-driven simulator.

Each ``table*`` function returns rows for run.py and prints a human-readable
block.  Defaults mirror the paper: 32 workers, 25 Gbps, half-duplex PS
(matches the paper's TF1.4-era measurements; see EXPERIMENTS.md).
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.sim import PAPER_CNNS, simulate, simulate_ps

MODELS = ["inception-v3", "vgg16", "resnet-101", "resnet-200"]
KW = dict(workers=32, bandwidth=25e9)
PS_KW = dict(half_duplex_ps=True)

PAPER_TABLE4 = {      # (agg, multicast, multicast+agg) from the paper
    "inception-v3": (1.34, 1.69, 3.28),
    "vgg16": (1.89, 1.94, 22.0),
    "resnet-101": (1.65, 1.79, 6.07),
    "resnet-200": (1.85, 1.85, 6.7),
}
PAPER_TABLE6 = {      # (ring, ring+multicast, butterfly)
    "vgg16": (24.6, 24.6, 11.3),
    "resnet-200": (6.75, 6.76, 6.79),
    "resnet-101": (6.55, 6.71, 6.46),
    "inception-v3": (3.35, 3.41, 3.41),
}


def table1_validation():
    """PS-count scaling (the paper validated sim vs real; we verify the same
    monotone plateau trend the paper's Table 1 shows)."""
    rows = []
    print("\n== Table 1 analogue: iteration time vs #PS (baseline PS) ==")
    for m in MODELS:
        times = []
        for nps in (1, 2, 4, 8):
            us, r = timed(lambda nps=nps: simulate_ps(
                PAPER_CNNS[m], num_ps=nps, **KW, **PS_KW).iteration_time)
            times.append(r)
            rows.append((f"table1/{m}/ps{nps}", us, f"{r:.3f}s"))
        trend = "ok" if times[0] >= times[-1] * 0.95 else "VIOLATED"
        print(f"  {m:14s} " + "  ".join(f"{t:7.3f}s" for t in times) +
              f"   plateau-trend: {trend}")
    return rows


def table4_in_network():
    rows = []
    print("\n== Table 4: PS + in-network mechanisms (speedup vs baseline) ==")
    print(f"  {'model':14s} {'agg':>6s} {'mc':>6s} {'mc+agg':>7s}   paper: agg/mc/mc+agg")
    for m in MODELS:
        tr = PAPER_CNNS[m]
        base = simulate("baseline", tr, **KW, **PS_KW).iteration_time
        vals = []
        for mech in ("agg", "multicast", "multicast+agg"):
            us, t = timed(lambda mech=mech: simulate(
                mech, tr, **KW, **PS_KW).iteration_time)
            vals.append(base / t)
            rows.append((f"table4/{m}/{mech}", us, f"{base / t:.2f}x"))
        p = PAPER_TABLE4[m]
        print(f"  {m:14s} {vals[0]:6.2f} {vals[1]:6.2f} {vals[2]:7.2f}"
              f"   {p[0]}/{p[1]}/{p[2]}")
    return rows


def table6_end_host():
    rows = []
    print("\n== Table 6: end-host mechanisms (speedup vs baseline) ==")
    print(f"  {'model':14s} {'ring':>6s} {'ring+mc':>8s} {'bfly':>6s}   paper")
    for m in MODELS:
        tr = PAPER_CNNS[m]
        base = simulate("baseline", tr, **KW, **PS_KW).iteration_time
        vals = []
        for mech in ("ring", "ring+multicast", "butterfly"):
            us, t = timed(lambda mech=mech: simulate(mech, tr, **KW).iteration_time)
            vals.append(base / t)
            rows.append((f"table6/{m}/{mech}", us, f"{base / t:.2f}x"))
        p = PAPER_TABLE6[m]
        print(f"  {m:14s} {vals[0]:6.2f} {vals[1]:8.2f} {vals[2]:6.2f}"
              f"   {p[0]}/{p[1]}/{p[2]}")
    return rows


def table8_assignment():
    rows = []
    print("\n== Table 8: even (split) PS assignment, 8 PS vs ring (seconds) ==")
    for m in MODELS:
        tr = PAPER_CNNS[m]
        multiagg = simulate_ps(tr, num_ps=1, multicast=True, in_network_agg=True,
                               **KW, **PS_KW).iteration_time
        ps8 = simulate_ps(tr, num_ps=8, assignment="split", multicast=True,
                          in_network_agg=True, **KW, **PS_KW).iteration_time
        ring = simulate("ring", tr, **KW).iteration_time
        rows.append((f"table8/{m}", 0.0,
                     f"multiagg={multiagg:.3f}s ps8split={ps8:.3f}s ring={ring:.3f}s"))
        print(f"  {m:14s} multiagg {multiagg:7.3f}s  8PS-split {ps8:7.3f}s  "
              f"ring {ring:7.3f}s")
    return rows


def table9_barrier():
    rows = []
    print("\n== Table 9: removing the PS global barrier (multicast+agg) ==")
    for m in MODELS:
        tr = PAPER_CNNS[m]
        kw = dict(multicast=True, in_network_agg=True, iterations=4, **KW, **PS_KW)
        with_b = simulate_ps(tr, barrier=True, **kw).iteration_time
        no_b = simulate_ps(tr, barrier=False, **kw).iteration_time
        ring = simulate("ring", tr, **KW).iteration_time
        rows.append((f"table9/{m}", 0.0,
                     f"barrier={with_b:.3f}s nobarrier={no_b:.3f}s ring={ring:.3f}s"))
        print(f"  {m:14s} barrier {with_b:7.3f}s  no-barrier {no_b:7.3f}s  "
              f"ring {ring:7.3f}s")
    return rows


def table10_block():
    rows = []
    print("\n== Table 10: block distribution vs in-network aggregation ==")
    for bw in (10e9, 100e9):
        for m in MODELS:
            tr = PAPER_CNNS[m]
            kw = dict(workers=32, bandwidth=bw, **PS_KW)
            agg = simulate_ps(tr, in_network_agg=True, **kw).iteration_time
            blk = simulate_ps(tr, distribution="block", **kw).iteration_time
            rows.append((f"table10/{m}/{bw / 1e9:.0f}g", 0.0,
                         f"agg={agg:.3f}s block={blk:.3f}s"))
            print(f"  {m:14s} {bw / 1e9:5.0f} Gbps  agg {agg:7.3f}s  "
                  f"block {blk:7.3f}s")
    return rows


def main():
    rows = []
    for fn in (table1_validation, table4_in_network, table6_end_host,
               table8_assignment, table9_barrier, table10_block):
        rows += fn()
    return emit(rows)


if __name__ == "__main__":
    main()
