"""Paper figures 3-12: bandwidth / worker / synthetic-model / compute sweeps."""
from __future__ import annotations

from benchmarks.common import emit
from repro.sim import INCEPTION_V3, PAPER_CNNS, RESNET_200, VGG16, simulate

MECHS = ["multicast+agg", "ring", "butterfly"]
PS_KW = dict(half_duplex_ps=True)


def _kw(mech, **kw):
    return {**kw, **(PS_KW if "agg" in mech or mech in ("baseline", "multicast")
                     else {})}


def fig3_5_bandwidth():
    rows = []
    print("\n== Figs 3-5: iteration time vs bandwidth (32 workers) ==")
    for model in ("inception-v3", "resnet-200", "vgg16"):
        tr = PAPER_CNNS[model]
        for bw in (5e9, 10e9, 25e9, 50e9, 100e9):
            vals = []
            for mech in MECHS:
                t = simulate(mech, tr, workers=32, bandwidth=bw,
                             **( _kw(mech))).iteration_time
                vals.append(t)
                rows.append((f"fig3_5/{model}/{mech}/{bw / 1e9:.0f}g", 0.0,
                             f"{t:.3f}s"))
            print(f"  {model:14s} {bw / 1e9:5.0f} Gbps  " +
                  "  ".join(f"{m}={v:7.3f}s" for m, v in zip(MECHS, vals)))
    return rows


def fig6_8_workers():
    rows = []
    print("\n== Figs 6-8: speedup vs worker count (25 Gbps) ==")
    for model in ("inception-v3", "resnet-200", "vgg16"):
        tr = PAPER_CNNS[model]
        for w in (4, 8, 16, 32):
            base = simulate("baseline", tr, workers=w, bandwidth=25e9,
                            **PS_KW).iteration_time
            vals = []
            for mech in MECHS:
                t = simulate(mech, tr, workers=w, bandwidth=25e9,
                             **_kw(mech)).iteration_time
                vals.append(base / t)
                rows.append((f"fig6_8/{model}/{mech}/w{w}", 0.0,
                             f"{base / t:.2f}x"))
            print(f"  {model:14s} W={w:3d}  " +
                  "  ".join(f"{m}={v:6.2f}x" for m, v in zip(MECHS, vals)))
    return rows


def fig9_10_synthetic():
    rows = []
    print("\n== Figs 9-10: synthetic future models (Inception-v3 + N modules) ==")
    for kind in ("network", "compute"):
        for n in (0, 25, 75, 125):
            tr = INCEPTION_V3.with_synthetic_modules(kind, n) if n else INCEPTION_V3
            base = simulate("baseline", tr, workers=32, bandwidth=25e9,
                            **PS_KW).iteration_time
            vals = []
            for mech in ("agg", "multicast", "multicast+agg", "ring", "butterfly"):
                t = simulate(mech, tr, workers=32, bandwidth=25e9,
                             **_kw(mech)).iteration_time
                vals.append((mech, base / t))
                rows.append((f"fig9_10/{kind}/{mech}/n{n}", 0.0, f"{base / t:.2f}x"))
            print(f"  {kind:8s} +{n:3d}  " +
                  " ".join(f"{m}={v:5.2f}x" for m, v in vals))
    return rows


def fig11_12_compute():
    rows = []
    print("\n== Figs 11-12: faster accelerators (compute scaled 1-4x) ==")
    for model in ("inception-v3", "resnet-200"):
        for f in (1.0, 1.5, 2.0, 2.5, 3.0, 4.0):
            tr = PAPER_CNNS[model].scaled(compute_factor=f)
            base = simulate("baseline", tr, workers=32, bandwidth=25e9,
                            **PS_KW).iteration_time
            vals = []
            for mech in MECHS:
                t = simulate(mech, tr, workers=32, bandwidth=25e9,
                             **_kw(mech)).iteration_time
                vals.append(base / t)
                rows.append((f"fig11_12/{model}/{mech}/x{f}", 0.0,
                             f"{base / t:.2f}x"))
            print(f"  {model:14s} x{f:<4}  " +
                  "  ".join(f"{m}={v:6.2f}x" for m, v in zip(MECHS, vals)))
    return rows


def main():
    rows = []
    for fn in (fig3_5_bandwidth, fig6_8_workers, fig9_10_synthetic,
               fig11_12_compute):
        rows += fn()
    return emit(rows)


if __name__ == "__main__":
    main()
