"""The paper's mechanisms as REAL collectives: wire bytes from compiled HLO.

On this CPU container wall-clock timing of collectives is meaningless, so the
benchmark reports the structural quantity that determines on-wire cost: the
trip-aware per-device collective bytes of each strategy's compiled gradient
sync for a fixed gradient pytree, on an 8-way DP mesh.  (Ring and
rabenseifner should be ~2(W-1)/W x payload; butterfly log2(W) x payload; PS
reduce-scatter+gather ~2x payload; int8 ring ~1/3.5 of fp32 ring.)

Runs in a subprocess (needs 8 fake devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.api import GradSync, GradSyncConfig
from repro.roofline.hlo_parse import collective_bytes_trip_aware

mesh = jax.make_mesh((8,), ("data",))
tree = {"a": jnp.zeros((1024, 256), jnp.float32), "b": jnp.zeros((512,), jnp.float32)}
payload = sum(x.size * 4 for x in jax.tree.leaves(tree))
results = {"payload": payload}
for strategy, comp in [("psum", ""), ("ring", ""), ("ring+multicast", ""),
                       ("butterfly", ""), ("rabenseifner", ""), ("ps", ""),
                       ("ring", "int8"), ("ring", "topk")]:
    sync = GradSync(GradSyncConfig(strategy=strategy, compression=comp,
                                   average=False), tree)
    res = sync.init_residuals()
    def body(tr):
        local = jax.tree.map(lambda x: x[0], tr)
        if comp == "topk":
            r = [jnp.zeros_like(x) for x in (res or [])]
            out, _ = sync(local, {"data": 8}, r)
        else:
            out, _ = sync(local, {"data": 8})
        return jax.tree.map(lambda x: x[None], out)
    big = jax.tree.map(lambda x: jnp.zeros((8,) + x.shape, x.dtype), tree)
    f = jax.jit(jax.shard_map(body, mesh=mesh,
                              in_specs=(jax.tree.map(lambda _: P("data"), tree),),
                              out_specs=jax.tree.map(lambda _: P("data"), tree),
                              check_vma=False))
    hlo = f.lower(big).compile().as_text()
    coll = collective_bytes_trip_aware(hlo, 8)
    results[f"{strategy}{'+' + comp if comp else ''}"] = coll.get("total", 0.0)
print("JSON:" + json.dumps(results))
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", CODE], env=env,
                       capture_output=True, text=True, timeout=900)
    line = [l for l in p.stdout.splitlines() if l.startswith("JSON:")]
    if not line:
        print("jax_strategies bench failed:", p.stdout[-1500:], p.stderr[-1500:])
        return emit([("jax_strategies/error", 0.0, "subprocess failed")])
    results = json.loads(line[0][5:])
    payload = results.pop("payload")
    print(f"\n== Strategy wire bytes (8-way DP, payload {payload / 1e6:.2f} MB) ==")
    rows = []
    for k, v in results.items():
        ratio = v / payload
        print(f"  {k:18s} {v / 1e6:10.3f} MB/device   {ratio:5.2f}x payload")
        rows.append((f"strategy_wire/{k}", 0.0, f"{ratio:.3f}x_payload"))
    return emit(rows)


if __name__ == "__main__":
    main()
