"""Roofline table from the dry-run artifacts (deliverable g, §Roofline)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.environ.get("DRYRUN_DIR", "dryrun_results_v2")


def load(mesh: str):
    d = os.path.join(RESULTS, mesh)
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def main():
    rows = []
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load(mesh)
        if not cells:
            continue
        print(f"\n== Roofline: {mesh} ==")
        print(f"  {'arch':24s}{'shape':12s}{'bound':11s}"
              f"{'comp(ms)':>9s}{'mem(ms)':>9s}{'coll(ms)':>9s}"
              f"{'MFU@bound':>10s}{'useful':>8s}")
        for c in cells:
            if c.get("skipped"):
                print(f"  {c['arch']:24s}{c['shape']:12s}SKIP: {c['skipped'][:48]}")
                rows.append((f"roofline/{mesh}/{c['arch']}/{c['shape']}", 0.0,
                             "skipped"))
                continue
            r = c["roofline"]
            print(f"  {c['arch']:24s}{c['shape']:12s}{r['bottleneck']:11s}"
                  f"{r['compute_s'] * 1e3:9.2f}{r['memory_s'] * 1e3:9.2f}"
                  f"{r['collective_s'] * 1e3:9.2f}"
                  f"{r['mfu_at_bound']:10.4f}{r['useful_flops_ratio']:8.3f}")
            rows.append((
                f"roofline/{mesh}/{c['arch']}/{c['shape']}",
                c.get("compile_s", 0) * 1e6,
                f"bound={r['bottleneck']};mfu={r['mfu_at_bound']:.4f}",
            ))
    return emit(rows)


if __name__ == "__main__":
    main()
