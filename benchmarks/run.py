"""Benchmark entry point: one section per paper table/figure + framework
benches.  Prints ``name,us_per_call,derived`` CSV rows (and readable blocks).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import jax_strategies, kernels_bench, paper_figures, paper_tables
    from benchmarks import roofline_report

    print("name,us_per_call,derived")
    sections = [
        ("paper_tables", paper_tables.main),
        ("paper_figures", paper_figures.main),
        ("jax_strategies", jax_strategies.main),
        ("kernels", kernels_bench.main),
        ("roofline", roofline_report.main),
    ]
    for name, fn in sections:
        t = time.time()
        try:
            fn()
        except Exception as e:  # a missing artifact must not kill the harness
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}")
        print(f"# section {name} took {time.time() - t:.1f}s")
    print(f"# total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
