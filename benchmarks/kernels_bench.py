"""Kernel-level microbench: XLA q-chunked attention vs naive attention
(wall time, CPU) and kernel-vs-ref agreement stats.

Interpret-mode Pallas timing is not meaningful (Python-executed), so the
wall-clock comparison is between the two XLA paths the model can use; the
Pallas kernels are validated for correctness and their BlockSpec geometry is
reported as the 'derived' column (VMEM working set per grid step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed


def attention_paths():
    from repro.models.attention import _chunked_attention

    B, S, H, hd = 2, 1024, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)

    def naive():
        s = jnp.einsum("bqhd,bkhd->bhqk", q / 8.0, k)
        mask = jnp.tril(jnp.ones((S, S), bool))
        p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    f_naive = jax.jit(naive)
    f_chunk = jax.jit(lambda: _chunked_attention(q, k, v, 0, 0.0, 256))
    a = f_naive().block_until_ready()
    b = f_chunk().block_until_ready()
    err = float(jnp.abs(a - b).max())
    t_naive, _ = timed(lambda: f_naive().block_until_ready(), repeats=3)
    t_chunk, _ = timed(lambda: f_chunk().block_until_ready(), repeats=3)
    rows = [
        ("attn/naive_xla", t_naive, f"S={S}"),
        ("attn/qchunked_xla", t_chunk, f"S={S};max_err={err:.1e}"),
    ]
    print(f"\n== attention paths (B{B} S{S} H{H} hd{hd}, CPU) ==")
    print(f"  naive     {t_naive / 1e3:8.1f} ms")
    print(f"  q-chunked {t_chunk / 1e3:8.1f} ms   (agreement {err:.1e})")
    return rows


def kernel_geometry():
    """Report VMEM working sets implied by the kernels' BlockSpecs."""
    rows = []
    print("\n== Pallas kernel VMEM working sets (per grid step) ==")
    flash = (128 * 128 * 4            # q block fp32 in VMEM scratch acc
             + 2 * 128 * 128 * 2      # k/v blocks bf16
             + 128 * 128 * 4 + 2 * 128 * 4)
    print(f"  flash_attention bq=bk=128 hd=128: {flash / 1024:.0f} KiB")
    rows.append(("kern/flash/vmem", 0.0, f"{flash}B"))
    mamba = (64 * 256 * 4 * 2 + 256 * 16 * 4 * 2 + 2 * 64 * 16 * 4)
    print(f"  mamba_scan bs=64 bd=256 N=16:     {mamba / 1024:.0f} KiB")
    rows.append(("kern/mamba/vmem", 0.0, f"{mamba}B"))
    q = 256 * 128 * (4 + 1) + 256 * 4
    print(f"  int8_quant rows=256:              {q / 1024:.0f} KiB")
    rows.append(("kern/int8/vmem", 0.0, f"{q}B"))
    return rows


def main():
    return emit(attention_paths() + kernel_geometry())


if __name__ == "__main__":
    main()
