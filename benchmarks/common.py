"""Shared benchmark helpers: timing + the run.py CSV contract."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def timed(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    """Median wall time (us) of fn() plus its last return value."""
    ts, out = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2], out


def emit(rows: Iterable[Row]) -> List[Row]:
    rows = list(rows)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
