"""Render dry-run artifacts into the EXPERIMENTS.md roofline tables."""
import json
import os
import sys


def load(d):
    out = []
    if not os.path.isdir(d):
        return out
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            out.append(json.load(open(os.path.join(d, f))))
    return out


def table(cells, caption):
    print(f"\n### {caption}\n")
    print("| arch | shape | kind | bottleneck | compute (ms) | memory (ms) | "
          "collective (ms) | step bound (ms) | MFU@bound | useful-FLOPs | "
          "wire GB/chip | compile (s) |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("skipped"):
            print(f"| {c['arch']} | {c['shape']} | — | SKIP (sub-quadratic "
                  f"attention required) | | | | | | | | |")
            continue
        r = c["roofline"]
        print(f"| {c['arch']} | {c['shape']} | {c['kind']} | "
              f"**{r['bottleneck']}** | {r['compute_s']*1e3:.2f} | "
              f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
              f"{r['step_lower_bound_s']*1e3:.2f} | {r['mfu_at_bound']:.4f} | "
              f"{r['useful_flops_ratio']:.3f} | "
              f"{c['collective_bytes'].get('total',0)/1e9:.2f} | "
              f"{c['compile_s']:.1f} |")


def memtable(cells, caption):
    print(f"\n### {caption}\n")
    print("| arch | shape | args (GB/chip) | temps (GB/chip) | out (GB/chip) |")
    print("|---|---|---|---|---|")
    for c in cells:
        if c.get("skipped"):
            continue
        m = c["memory_analysis"]
        print(f"| {c['arch']} | {c['shape']} | "
              f"{m.get('argument_size_in_bytes',0)/1e9:.2f} | "
              f"{m.get('temp_size_in_bytes',0)/1e9:.2f} | "
              f"{m.get('output_size_in_bytes',0)/1e9:.2f} |")


if __name__ == "__main__":
    base = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results_v2"
    table(load(os.path.join(base, "pod16x16")), "Single pod (16x16 = 256 chips)")
    table(load(os.path.join(base, "pod2x16x16")), "Multi-pod (2x16x16 = 512 chips)")
    memtable(load(os.path.join(base, "pod16x16")),
             "memory_analysis per chip (single pod)")
