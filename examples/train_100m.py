"""Train a ~100M-parameter qwen-family model on the synthetic pipeline.

The full run (a few hundred steps at batch 32 x 512) is sized for a single
accelerator host; on this CPU container pass ``--smoke`` to run the same
driver at toy scale, or lower ``--steps``.

    PYTHONPATH=src python examples/train_100m.py --steps 300       # full
    PYTHONPATH=src python examples/train_100m.py --smoke --steps 30
"""
import argparse
import dataclasses

import repro.configs as C
from repro.configs.base import ModelConfig
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer

# ~103M params: 12L, d=768, 12H, GLU ffn 2048, 32k vocab
LM_100M = ModelConfig(
    name="lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=2048,
    vocab_size=32_768,
    tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=512)
    args = ap.parse_args()

    cfg = C.reduced(LM_100M) if args.smoke else LM_100M
    # register so the Trainer can resolve it by name
    C.ARCHS[cfg.name] = cfg
    print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.1f}M params")

    tcfg = TrainConfig(
        arch=cfg.name,
        smoke=False,
        steps=args.steps,
        log_every=10,
        batch_override=4 if args.smoke else args.batch,
        seq_override=128 if args.smoke else args.seq,
        opt=OptConfig(lr=6e-4, warmup_steps=min(50, args.steps // 4),
                      total_steps=max(args.steps, 300)),
    )
    # bypass shape registry: the Trainer builds a custom shape from overrides
    tcfg = dataclasses.replace(tcfg, shape="train_4k")
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    print(f"\nloss {res['first_loss']:.3f} -> {res['last_loss']:.3f} over "
          f"{res['steps']} steps")


if __name__ == "__main__":
    main()
