"""Run the paper's gradient-sync strategies as REAL collectives on an 8-way
DP mesh (fake CPU devices) and verify they train identically.

    PYTHONPATH=src python examples/strategies_on_mesh.py
"""
import os
import subprocess
import sys

INNER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer

losses = {}
for strategy in ("gspmd", "ring", "butterfly", "ps", "rabenseifner"):
    tcfg = TrainConfig(arch="qwen1.5-0.5b", smoke=True, steps=6, log_every=0,
                       strategy=strategy, batch_override=8, seq_override=64,
                       opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    losses[strategy] = res["last_loss"]
    print(f"  {strategy:12s} final loss {res['last_loss']:.4f}")
ref = losses["gspmd"]
for k, v in losses.items():
    assert abs(v - ref) < 0.05, (k, v, ref)
print("all strategies converge identically OK")
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    print("training the same model under each paper strategy (8-way DP):")
    p = subprocess.run([sys.executable, "-c", INNER], env=env, timeout=1800)
    raise SystemExit(p.returncode)


if __name__ == "__main__":
    main()
