"""Quickstart: train a tiny qwen-family LM on the synthetic pipeline (CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer


def main():
    tcfg = TrainConfig(
        arch="qwen1.5-0.5b",
        smoke=True,                       # reduced config: ~0.4M params
        steps=60,
        log_every=10,
        batch_override=8,
        seq_override=128,
        opt=OptConfig(lr=2e-3, warmup_steps=10, total_steps=200),
    )
    trainer = Trainer(tcfg)
    trainer.init_or_restore()
    res = trainer.run()
    print(f"\nloss {res['first_loss']:.3f} -> {res['last_loss']:.3f} "
          f"in {res['steps']} steps ({res['median_step_s'] * 1e3:.0f} ms/step)")
    assert res["last_loss"] < res["first_loss"]


if __name__ == "__main__":
    main()
