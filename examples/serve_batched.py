"""End-to-end driver: briefly train a small model, checkpoint it, then serve
batched requests through the slot-based engine (prefill + decode with KV
cache / SSM state).

    PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.optim import OptConfig
from repro.serving import Request, ServeConfig, ServingEngine
from repro.train import TrainConfig, Trainer
from repro.train import checkpoint as ckpt


def main():
    arch = "gemma2-2b"
    with tempfile.TemporaryDirectory() as d:
        # 1) train briefly so served logits are not random noise
        tcfg = TrainConfig(
            arch=arch, smoke=True, steps=30, log_every=10,
            batch_override=8, seq_override=128, ckpt_dir=d,
            opt=OptConfig(lr=2e-3, warmup_steps=5, total_steps=100),
        )
        tr = Trainer(tcfg)
        tr.init_or_restore()
        tr.run()
        tr.save()

        # 2) restore into a serving engine
        cfg = reduced(get_config(arch))
        like = init_params(jax.random.PRNGKey(0), cfg)
        step = ckpt.latest_step(d + "/params")
        params = ckpt.restore_checkpoint(d + "/params", step, like)
        eng = ServingEngine(cfg, params, ServeConfig(slots=4, max_len=256,
                                                     temperature=0.0))

        # 3) serve a batched workload
        rng = np.random.default_rng(0)
        reqs = [
            Request(prompt=list(rng.integers(1, 400, size=rng.integers(4, 16))),
                    max_new=24)
            for _ in range(10)
        ]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in reqs)
        print(f"\nserved {len(reqs)} requests / {toks} tokens in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s on CPU)")
        print("sample:", reqs[0].out[:12])
        assert all(r.done and len(r.out) == 24 for r in reqs)


if __name__ == "__main__":
    main()
