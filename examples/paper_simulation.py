"""Reproduce the paper's headline result with the trace-driven simulator:

  "an individual end-host mechanism outperforms joint usage of network
   support" — ring-reduce >= multicast + in-network aggregation, and the full
   mechanism ranking of §8.7.

    PYTHONPATH=src python examples/paper_simulation.py
"""
from repro.sim import PAPER_CNNS, simulate

MECHS = ["agg", "multicast", "butterfly", "multicast+agg", "ring"]
PS = {"agg", "multicast", "multicast+agg", "baseline"}


def main():
    print("speedup over no-network-support PS baseline "
          "(32 workers, 25 Gbps, half-duplex PS):\n")
    print(f"{'model':14s} " + " ".join(f"{m:>14s}" for m in MECHS))
    ranking_points = {m: 0.0 for m in MECHS}
    for name, tr in PAPER_CNNS.items():
        base = simulate("baseline", tr, 32, 25e9, half_duplex_ps=True).iteration_time
        row = []
        for m in MECHS:
            kw = dict(half_duplex_ps=True) if m in PS else {}
            s = base / simulate(m, tr, 32, 25e9, **kw).iteration_time
            ranking_points[m] += s
            row.append(s)
        print(f"{name:14s} " + " ".join(f"{v:14.2f}" for v in row))

    order = sorted(MECHS, key=lambda m: -ranking_points[m])
    print("\naggregate ranking (ours):   " + " > ".join(order))
    print("paper ranking (§8.7):       ring > multicast+agg > butterfly > "
          "multicast > agg")
    assert order[-2:] == ["multicast", "agg"] or order[-2:] == ["agg", "multicast"]


if __name__ == "__main__":
    main()
