"""Optimizer, data pipeline, checkpointing, fault tolerance, serving."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, make_pipeline
from repro.configs import ARCHS, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.optim import OptConfig, apply_updates, init_opt_state, lr_at
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import StepWatchdog, retry_step


# ------------------------------------------------------------------ optimizer
def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array([2.0])}
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0,
                    clip_norm=0)
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.05)


def test_grad_clipping_caps_update_norm():
    params = {"w": jnp.zeros((4,))}
    cfg = OptConfig(lr=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0)
    state = init_opt_state(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, state2, m = apply_updates(params, g, state, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(jax.tree.leaves(state2["m"])[0]).max()) <= 0.11


# ----------------------------------------------------------------------- data
def test_pipeline_deterministic_and_shifted():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    shape = ShapeConfig("t", 64, 4, "train")
    p1 = make_pipeline(DataConfig(seed=7), cfg, shape)
    p2 = make_pipeline(DataConfig(seed=7), cfg, shape)
    b1, b2 = p1.batch_at(3), p2.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    b3 = p1.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_prefetch_thread():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    p = make_pipeline(DataConfig(seed=1), cfg, ShapeConfig("t", 32, 2, "train"))
    p.start(first_step=5)
    step, batch = p.next()
    assert step == 5 and batch["tokens"].shape == (2, 32)
    p.stop()


def test_memmap_pipeline(tmp_path):
    toks = (np.arange(100_000) % 1000).astype(np.uint16)
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    cfg = reduced(get_config("qwen1.5-0.5b"))
    p = make_pipeline(DataConfig(kind="memmap", path=str(f)), cfg,
                      ShapeConfig("t", 16, 2, "train"))
    b = p.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].max() < cfg.vocab_size


# ----------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "n": {"b": jnp.ones((4,), jnp.float32), "s": jnp.zeros((), jnp.int32)},
    }
    ckpt.save_checkpoint(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    out = ckpt.restore_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(5):
        ckpt.save_checkpoint(str(tmp_path), s, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_background_write(tmp_path):
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    t = ckpt.save_checkpoint(str(tmp_path), 1, tree, background=True)
    t.join(5)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save_checkpoint(str(tmp_path), 0, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="mismatch"):
        ckpt.restore_checkpoint(str(tmp_path), 0, {"x": jnp.zeros((5,))})


def test_partial_checkpoint_ignored(tmp_path):
    ckpt.save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros((2,))})
    # a torn write: directory without manifest
    os.makedirs(tmp_path / "step_000000009")
    assert ckpt.latest_step(str(tmp_path)) == 3


# ------------------------------------------------------------ fault tolerance
def test_watchdog_flags_stragglers():
    events = []
    wd = StepWatchdog(factor=3.0, on_straggler=lambda s, dt, med: events.append(s))
    for i in range(20):
        wd.record(i, 0.1)
    assert not wd.record(20, 0.15)
    assert wd.record(21, 1.0)
    assert events == [21]


def test_retry_step_recovers():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("transient collective timeout")
        return x + 1

    assert retry_step(flaky, 41, retries=3, backoff=0.01) == 42
    assert len(calls) == 3


def test_retry_step_exhausts():
    def always_fails():
        raise RuntimeError("dead chip")

    with pytest.raises(RuntimeError):
        retry_step(always_fails, retries=1, backoff=0.01)


# --------------------------------------------------------------------- serving
def test_serving_greedy_matches_manual_decode():
    import dataclasses

    from repro.models import decode_step, init_params, prefill
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = reduced(ARCHS["qwen1.5-0.5b"])
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = [5, 9, 2, 14]
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=64))
    req = Request(prompt=list(prompt), max_new=6)
    eng.run([req])

    # manual greedy decode, same prompt at full batch shape
    B = 2
    toks = np.zeros((B, len(prompt)), np.int32)
    toks[0] = prompt
    logits, cache = jax.jit(lambda p, b: prefill(p, b, cfg, max_len=64))(
        params, {"tokens": jnp.asarray(toks)}
    )
    out = []
    cur = np.asarray(logits, np.float32).argmax(-1)
    for _ in range(6):
        out.append(int(cur[0]))
        logits, cache = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))(
            params, jnp.asarray(cur[:, None].astype(np.int32)), cache
        )
        cur = np.asarray(logits, np.float32).argmax(-1)
    assert req.out == out
