"""Simulator: golden numbers from the paper + structural invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.sim import (
    INCEPTION_V3,
    VGG16,
    simulate,
    simulate_ps,
    toy_3op,
)
from repro.sim.strategies import _ring_chunks
from repro.sim.traces import LayerTrace, ModelTrace


# ----------------------------------------------------------- paper §8.1.1 / Fig 2
def _agg_window(res, W=2, it=0):
    sim = res.sim
    bp_start = min(sim.start_time[("bp", it, w, 2)] for w in range(W))
    return sim.end_time[("barrier", it)] - bp_start


def test_toy_baseline_staggered_21s():
    trace = toy_3op()
    r = simulate_ps(trace, workers=2, bandwidth=1e9, iterations=1)
    assert _agg_window(r) == pytest.approx(21.0)


def test_toy_agg_staggered_28pct():
    trace = toy_3op()
    r = simulate_ps(trace, workers=2, bandwidth=1e9, iterations=1, in_network_agg=True)
    w = _agg_window(r)
    assert w == pytest.approx(15.0)
    assert (21 - w) / 21 == pytest.approx(0.2857, abs=1e-3)


def test_toy_agg_simultaneous_43pct():
    trace = toy_3op()
    r = simulate_ps(trace, workers=2, bandwidth=1e9, iterations=1,
                    multicast=True, in_network_agg=True)
    assert _agg_window(r) == pytest.approx(12.0)


# ------------------------------------------------------------------ rankings
@pytest.mark.parametrize("trace", [INCEPTION_V3, VGG16], ids=lambda t: t.name)
def test_mc_agg_beats_parts(trace):
    """Table 4: multicast+agg beats either mechanism alone; both beat baseline
    (the mc-vs-agg gap grows with worker count; at W=8 it can be a tie)."""
    kw = dict(workers=8, bandwidth=25e9, half_duplex_ps=True)
    base = simulate("baseline", trace, **kw).iteration_time
    agg = simulate("agg", trace, **kw).iteration_time
    mc = simulate("multicast", trace, **kw).iteration_time
    both = simulate("multicast+agg", trace, **kw).iteration_time
    assert both < min(mc, agg) * 1.02
    # at W=8 a compute-bound model (inception) can tie agg with baseline
    assert max(mc, agg) <= base * 1.001


def test_ring_beats_butterfly_for_network_bound_model():
    """§8.2.3: ring > butterfly for VGG16 (network-bound backprop)."""
    ring = simulate("ring", VGG16, workers=8, bandwidth=25e9).iteration_time
    bf = simulate("butterfly", VGG16, workers=8, bandwidth=25e9).iteration_time
    assert ring < bf


def test_ring_multicast_equivalent_to_ring():
    """§8.4: multicast in the second ring has very limited impact."""
    ring = simulate("ring", INCEPTION_V3, workers=8, bandwidth=25e9).iteration_time
    rmc = simulate("ring+multicast", INCEPTION_V3, workers=8,
                   bandwidth=25e9).iteration_time
    assert abs(ring - rmc) / ring < 0.10


def test_messaging_helps_vgg():
    """§8.2.1/§9.2: parameter messaging rescues ring from the 5.44Gb layer."""
    msg = simulate("ring", VGG16, workers=8, bandwidth=10e9).iteration_time
    nomsg = simulate("ring_nomsg", VGG16, workers=8, bandwidth=10e9).iteration_time
    assert msg < nomsg


def test_end_host_competitive_with_fabric():
    """Headline claim (as reproducible with synthesized traces): ring is the
    best end-host mechanism and lands within ~35% of multicast+agg without
    touching the fabric.  (The paper has ring ahead by ~12% for VGG16; our
    per-layer trace synthesis from the aggregate tables flips that tail —
    deviation documented in EXPERIMENTS.md §Paper-validation.)"""
    kw = dict(workers=8, bandwidth=25e9)
    ring = simulate("ring", VGG16, **kw).iteration_time
    bf = simulate("butterfly", VGG16, **kw).iteration_time
    both = simulate("multicast+agg", VGG16, half_duplex_ps=True, **kw).iteration_time
    assert ring <= bf
    assert ring <= both * 1.35


# ---------------------------------------------------------------- §9 robustness
def test_no_barrier_helps_ps():
    kw = dict(workers=8, bandwidth=25e9, multicast=True, in_network_agg=True)
    with_b = simulate_ps(INCEPTION_V3, barrier=True, iterations=4, **kw).iteration_time
    no_b = simulate_ps(INCEPTION_V3, barrier=False, iterations=4, **kw).iteration_time
    assert no_b <= with_b * 1.02


def test_block_distribution_competitive_with_agg():
    """Table 10: block distribution ~ in-network aggregation."""
    blk = simulate_ps(VGG16, workers=8, bandwidth=10e9,
                      distribution="block").iteration_time
    agg = simulate_ps(VGG16, workers=8, bandwidth=10e9,
                      in_network_agg=True).iteration_time
    assert blk < agg * 1.35


def test_split_assignment_beats_round_robin_for_vgg():
    """Table 8: splitting the 5.44Gb FC across PSs helps VGG16."""
    rr = simulate_ps(VGG16, workers=8, num_ps=4, bandwidth=25e9,
                     assignment="round_robin").iteration_time
    sp = simulate_ps(VGG16, workers=8, num_ps=4, bandwidth=25e9,
                     assignment="split").iteration_time
    assert sp < rr


# ------------------------------------------------------------------- properties
@settings(max_examples=20, deadline=None)
@given(
    bw=st.sampled_from([5e9, 10e9, 25e9, 100e9]),
    mech=st.sampled_from(["baseline", "multicast", "ring", "butterfly"]),
)
def test_more_bandwidth_never_slower(bw, mech):
    t1 = simulate(mech, INCEPTION_V3, workers=4, bandwidth=bw).iteration_time
    t2 = simulate(mech, INCEPTION_V3, workers=4, bandwidth=bw * 2).iteration_time
    assert t2 <= t1 * 1.001


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 12),
    w=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 100),
)
def test_ring_chunks_partition_total(n, w, seed):
    import random

    rnd = random.Random(seed)
    layers = [LayerTrace(f"l{i}", rnd.uniform(1e6, 1e9), 0.01, 0.01)
              for i in range(n)]
    tr = ModelTrace("t", layers, 0.0, jitter=0.0)
    chunks = _ring_chunks(tr, w, messaging=True)
    assert len(chunks) == w
    assert sum(c[0] for c in chunks) == pytest.approx(tr.total_bits, rel=1e-6)
    assert all(0 <= c[1] < n for c in chunks)


def test_compute_speedup_crossover():
    """§8.6: with much faster compute, PS+mc+agg catches ring (Figs 11-12)."""
    kw = dict(workers=8, bandwidth=25e9)
    gap = []
    for f in (1.0, 4.0):
        tr = INCEPTION_V3.scaled(compute_factor=f)
        ring = simulate("ring", tr, **kw).iteration_time
        both = simulate("multicast+agg", tr, **kw).iteration_time
        gap.append(both / ring)
    assert gap[1] < gap[0]  # fabric support gains ground as compute shrinks


def test_synthetic_modules_change_totals():
    tr = INCEPTION_V3.with_synthetic_modules("network", 10)
    assert len(tr.layers) == len(INCEPTION_V3.layers) + 10
    assert tr.total_bits > INCEPTION_V3.total_bits
