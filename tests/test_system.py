"""End-to-end system behaviour: train -> checkpoint -> resume -> serve."""
import numpy as np
import pytest

from repro.optim import OptConfig
from repro.train import TrainConfig, Trainer


def test_train_loss_decreases_and_resumes(tmp_path):
    tcfg = TrainConfig(
        arch="qwen1.5-0.5b", smoke=True, steps=12, log_every=0,
        ckpt_dir=str(tmp_path), ckpt_every=5,
        opt=OptConfig(lr=1e-3, warmup_steps=5, total_steps=100),
    )
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    assert res["steps"] == 12
    assert res["last_loss"] < res["first_loss"]

    tr2 = Trainer(tcfg)
    tr2.init_or_restore()
    assert tr2.step == 10          # restored from the step-10 checkpoint
    res2 = tr2.run(3)
    assert np.isfinite(res2["last_loss"])


def test_resume_is_deterministic(tmp_path):
    """Same seed + stateless data pipeline => resumed run equals straight run."""
    base = dict(arch="qwen1.5-0.5b", smoke=True, log_every=0,
                opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=100))
    # straight 8-step run
    t1 = Trainer(TrainConfig(steps=8, **base))
    t1.init_or_restore()
    r1 = t1.run()
    # 4 steps, checkpoint, resume 4 more
    d = str(tmp_path / "ck")
    t2 = Trainer(TrainConfig(steps=4, ckpt_dir=d, ckpt_every=4, **base))
    t2.init_or_restore()
    t2.run()
    t3 = Trainer(TrainConfig(steps=4, ckpt_dir=d, **base))
    t3.init_or_restore()
    assert t3.step == 4
    r3 = t3.run(4)
    assert r3["last_loss"] == pytest.approx(r1["last_loss"], rel=1e-3)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "falcon-mamba-7b",
                                  "jamba-v0.1-52b", "seamless-m4t-large-v2"])
def test_train_smoke_nontrivial_families(arch):
    tcfg = TrainConfig(arch=arch, smoke=True, steps=3, log_every=0,
                       batch_override=4, seq_override=64,
                       opt=OptConfig(lr=5e-4, warmup_steps=2, total_steps=50))
    tr = Trainer(tcfg)
    tr.init_or_restore()
    res = tr.run()
    assert np.isfinite(res["last_loss"])


def test_grad_accum_equivalent_loss_scale():
    """2-way accumulation trains comparably to the flat batch."""
    base = dict(arch="qwen1.5-0.5b", smoke=True, steps=6, log_every=0,
                batch_override=8, seq_override=64,
                opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=100))
    flat = Trainer(TrainConfig(grad_accum=1, **base))
    flat.init_or_restore()
    r1 = flat.run()
    acc = Trainer(TrainConfig(grad_accum=2, **base))
    acc.init_or_restore()
    r2 = acc.run()
    assert abs(r1["last_loss"] - r2["last_loss"]) < 0.35
