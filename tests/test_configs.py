"""Config registry + analytic param counts vs published sizes."""
import pytest

from repro.configs import ARCHS, LONG_CONTEXT_ARCHS, all_cells, get_config, reduced


def test_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize(
    "arch,expected,tol",
    [
        ("qwen1.5-0.5b", 0.62e9, 0.30),       # HF reports 0.62B total
        ("starcoder2-3b", 3.0e9, 0.20),
        ("gemma2-2b", 2.6e9, 0.20),
        ("llama3-405b", 405e9, 0.05),
        ("falcon-mamba-7b", 7.3e9, 0.15),
        # assigned dims (48L x 64e x d_ff 1408) analytically give ~29B total;
        # the released Moonlight-16B has 27 layers — we implement the
        # assignment as specified (active params ~4.6B, within a3b spirit)
        ("moonshot-v1-16b-a3b", 28.9e9, 0.10),
        ("mixtral-8x7b", 46.7e9, 0.05),
        ("chameleon-34b", 34e9, 0.10),
        ("jamba-v0.1-52b", 52e9, 0.10),
        ("seamless-m4t-large-v2", 2.3e9, 0.35),  # backbone only (frontend stubbed)
    ],
)
def test_param_counts(arch, expected, tol):
    n = get_config(arch).param_count()
    assert abs(n - expected) / expected < tol, f"{arch}: {n / 1e9:.2f}B vs {expected / 1e9}B"


def test_cell_matrix():
    cells = all_cells()
    assert len(cells) == 40
    skipped = [c for c in cells if not c.runnable]
    assert {c.arch for c in skipped} == set(ARCHS) - LONG_CONTEXT_ARCHS
    assert all(c.shape == "long_500k" for c in skipped)
    assert all(c.skip_reason for c in skipped)


def test_reduced_preserves_family_structure():
    for name, cfg in ARCHS.items():
        r = reduced(cfg)
        assert r.family == cfg.family
        assert (r.moe is None) == (cfg.moe is None)
        assert (r.ssm is None) == (cfg.ssm is None)
        assert r.is_encoder_decoder == cfg.is_encoder_decoder
        assert (r.sliding_window > 0) == (cfg.sliding_window > 0)
        assert r.param_count() < 5e6


def test_interleave_patterns():
    jamba = get_config("jamba-v0.1-52b")
    attn_layers = [i for i in range(32) if jamba.is_attn_layer(i)]
    assert attn_layers == [4, 12, 20, 28]
    moe_layers = [i for i in range(32) if jamba.is_moe_layer(i)]
    assert moe_layers == list(range(1, 32, 2))
    gemma = get_config("gemma2-2b")
    assert gemma.is_local_layer(0) and not gemma.is_local_layer(1)
