"""Bucketing / assignment / compression invariants (hypothesis where useful)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bucketing as B
from repro.core.compression import dequantize_int8, quantize_int8


def _leaves(sizes):
    return [B.Leaf(f"p{i}", (s,), s, jnp.float32) for i, s in enumerate(sizes)]


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 10_000), min_size=1, max_size=40),
    owners=st.integers(1, 8),
)
def test_size_balanced_no_worse_than_round_robin(sizes, owners):
    rr = B.assign_owners(sizes, owners, "round_robin")
    sb = B.assign_owners(sizes, owners, "size_balanced")
    _, rr_max, _ = B.imbalance(sizes, rr, owners)
    _, sb_max, _ = B.imbalance(sizes, sb, owners)
    assert sb_max <= rr_max + 1e-9


def test_imbalance_reproduces_table7_shape():
    """A VGG-like size profile under round-robin: max% far above ideal."""
    sizes = [30e6] * 15 + [5440e6]          # 15 convs + giant FC
    owners = B.assign_owners(sizes, 4, "round_robin")
    mn, mx, ideal = B.imbalance(sizes, owners, 4)
    assert mx > 0.85 and ideal == 0.25      # paper Table 7: 0.918 for 4 PS
    sb = B.assign_owners(sizes, 4, "size_balanced")
    _, mx2, _ = B.imbalance(sizes, sb, 4)
    assert mx2 < mx


@settings(max_examples=20, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 5_000), min_size=1, max_size=20),
    target=st.integers(1024, 64 * 1024),
)
def test_buckets_cover_all_leaves_once(sizes, target):
    leaves = _leaves(sizes)
    buckets = B.build_buckets(leaves, target_bytes=target)
    seen = [i for b in buckets for i in b.leaf_ids]
    assert sorted(seen) == list(range(len(sizes)))


def test_pack_unpack_roundtrip():
    leaves = _leaves([7, 130, 33])
    arrs = [jnp.arange(s, dtype=jnp.float32) + i for i, s in enumerate([7, 130, 33])]
    bucket = B.Bucket((0, 1, 2), sum(x.size * 4 for x in arrs))
    buf = B.pack(arrs, bucket, align=64)
    assert buf.size % 64 == 0
    out = B.unpack(buf, bucket, leaves)
    for i, a in enumerate(arrs):
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(a))


def test_chunk_buckets_respects_message_size():
    leaves = _leaves([100, 100, 100, 100])
    buckets = B.build_buckets(leaves, target_bytes=1 << 20)
    chunked = B.chunk_buckets(buckets, leaves, max_message_bytes=450)
    assert len(chunked) > len(buckets)
    for c in chunked:
        assert len(c.leaf_ids) == 1 or c.bytes <= 450


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.01, 100.0))
def test_int8_quant_bounded_error(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1024,)) * scale
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    blockmax = np.abs(np.asarray(x).reshape(-1, 128)).max(1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x)).reshape(-1, 128)
    assert (err <= blockmax / 127.0 * 0.51 + 1e-9).all()
