"""Roofline accounting: shape parsing, trip-count-aware HLO traversal."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import _shape_bytes, collective_bytes_from_hlo
from repro.roofline.hlo_parse import (
    collective_bytes_trip_aware,
    computation_multipliers,
)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2], bf16[4])") == 24
    assert _shape_bytes("s32[]") == 4


SYNTH = """
HloModule m

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[32]{0} all-gather(%a), replica_groups=[2,4]<=[8], dimensions={0}
  %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""


def test_trip_count_multipliers():
    comps, mult = computation_multipliers(SYNTH)
    assert mult["body"] == 24
    assert mult["cond"] == 24
    assert mult["main"] == 1


def test_trip_aware_collective_bytes():
    out = collective_bytes_trip_aware(SYNTH, total_devices=8)
    # all-reduce: f32[8]=32B, W=4 -> 2*(3/4)*32 = 48B per iteration x 24 trips
    assert out["all-reduce"] == pytest.approx(48 * 24)
    # all-gather: result f32[32]=128B, W=4 (iota groups [2,4]) -> (3/4)*128
    assert out["all-gather"] == pytest.approx(96)
    # naive (trip-unaware) parse undercounts the loop body
    naive = collective_bytes_from_hlo(SYNTH, total_devices=8)
    assert naive["all-reduce"] == pytest.approx(48)


def test_real_compiled_scan_is_trip_counted():
    """End to end: a compiled jax scan with a psum inside must be multiplied."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((4,), ("d",))

def f(x):
    def body(c, _):
        return c + jax.lax.psum(c, "d"), None
    y, _ = jax.lax.scan(body, x, None, length=10)
    return y

fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P("d")))
hlo = fn.lower(jnp.zeros((4, 64), jnp.float32)).compile().as_text()
from repro.roofline.hlo_parse import collective_bytes_trip_aware
out = collective_bytes_trip_aware(hlo, 4)
# per-device psum buffer: f32[64] = 256B; 2*(3/4)*256 = 384B x 10 trips
expected = 384 * 10
assert abs(out["all-reduce"] - expected) / expected < 0.01, out
print("OK", out["all-reduce"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "OK" in p.stdout


def test_roofline_terms_bottleneck_selection():
    from repro.roofline import roofline_terms

    terms = roofline_terms(
        {"flops": 197e12, "bytes accessed": 1e9}, {"ici": 1e9, "total": 1e9},
        chips=256, model_fl=197e12 * 256 * 0.5,
    )
    assert terms["bottleneck"] == "compute"
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["mfu_at_bound"] == pytest.approx(0.5)
