"""Multi-device collective checks run in a subprocess (8 fake CPU devices).

The main pytest process must keep a single device (smoke tests depend on it),
so the device-count flag lives in the child only.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run(
        [sys.executable, "-m", "repro.core.dist_checks"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    lines = [l for l in p.stdout.splitlines() if l.startswith("{")]
    assert lines, f"no JSON from dist_checks: {p.stdout[-2000:]}\n{p.stderr[-2000:]}"
    return json.loads(lines[-1])


CHECKS = [
    "check_ring", "check_ring_multicast", "check_butterfly",
    "check_rabenseifner", "check_ps", "check_reduce_scatter",
    "check_all_gather", "check_hierarchical", "check_int8", "check_topk",
    "check_gradsync_tree", "check_explicit_strategies_match_gspmd",
    "check_hierarchical_train_step",
]


@pytest.mark.parametrize("name", CHECKS)
def test_collective(dist_results, name):
    assert dist_results.get(name) == "ok", dist_results.get(name)
