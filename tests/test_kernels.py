"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as F
from repro.kernels.flash_attention import ref as FR
from repro.kernels.int8_quant import kernel as QK
from repro.kernels.int8_quant.ref import dequantize_ref, quantize_ref
from repro.kernels.mamba_scan import ops as MS
from repro.kernels.mamba_scan.ref import selective_scan_ref

FLASH_CASES = [
    # (B, S, H, Hk, hd)
    (2, 256, 4, 2, 64),
    (1, 128, 8, 8, 128),
    (2, 384, 6, 2, 80),      # non-128 head dim: exercises padding path
    (1, 256, 4, 1, 64),      # MQA
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 128, 0.0), (True, 0, 30.0),
    (False, 0, 0.0), (True, 128, 50.0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, causal, window, softcap, dtype):
    B, S, H, Hk, hd = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, hd), dtype)
    got = F.flash_attention(q, k, v, causal=causal, window=window, softcap=softcap)
    want = FR.attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, window=window, softcap=softcap,
    ).swapaxes(1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-5
    err = np.abs(np.asarray(got, np.float32) - np.asarray(want, np.float32)).max()
    assert err < tol, err


def test_flash_attention_gradients_flow():
    B, S, H, hd = 1, 128, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))

    def loss_kernel(q, k, v):
        return jnp.sum(F.flash_attention(q, k, v) ** 2)

    def loss_ref(q, k, v):
        o = FR.attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2))
        return jnp.sum(o.swapaxes(1, 2) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


MAMBA_CASES = [(2, 128, 256, 16), (1, 64, 512, 8), (2, 192, 256, 16)]


@pytest.mark.parametrize("case", MAMBA_CASES, ids=str)
def test_mamba_scan_matches_ref(case):
    B, S, D, N = case
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jax.random.normal(ks[5], (B, D, N)) * 0.1
    y, h = MS.selective_scan(x, dt, A, Bm, Cm, h0)
    yr, hr = selective_scan_ref(x, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4, atol=1e-4)


def test_mamba_kernel_matches_model_path():
    from repro.models.mamba import selective_scan as model_scan

    B, S, D, N = 2, 128, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    h0 = jnp.zeros((B, D, N))
    yk, _ = MS.selective_scan(x, dt, A, Bm, Cm, h0)
    ym, _ = model_scan(x, dt, A, Bm, Cm, h0, chunk=64)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(ym), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [128 * 8, 128 * 100, 128 * 33])
def test_int8_kernels_match_ref(n):
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    q, s = QK.quantize_pallas(x)
    qr, sr = quantize_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    d = QK.dequantize_pallas(q, s)
    np.testing.assert_allclose(np.asarray(d), np.asarray(dequantize_ref(qr, sr)),
                               rtol=1e-6)


def test_model_attention_flash_path_matches_xla_path():
    """The model-level use_flash flag must not change results."""
    import dataclasses

    from repro.configs import ARCHS, reduced
    from repro.models import init_params
    from repro.models.attention import attention

    cfg = dataclasses.replace(reduced(ARCHS["gemma2-2b"]), head_dim=64)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sub = jax.tree.map(lambda x: x[0], params["blocks"])["sub0"]["attn"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model), jnp.float32)
    a, _ = attention(sub, x, cfg, local=True, use_flash=False)
    b, _ = attention(sub, x, cfg, local=True, use_flash=True)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=2e-2, atol=2e-2)
