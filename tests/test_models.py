"""Per-arch smoke tests + decode/forward consistency (reduced configs, CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import decode_step, forward, init_params, prefill
from repro.models import layers as L
from repro.models.model import _encode, head_table, loss_fn

ALL = sorted(ARCHS)


def _cfg(name, exact_moe=True):
    cfg = reduced(ARCHS[name])
    if exact_moe and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.0)
        )
    return cfg


def _batch(cfg, B=2, S=32, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, S, cfg.d_model)
        ).astype(jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL)
def test_forward_loss_finite(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, _batch(cfg))
    assert jnp.isfinite(loss), (arch, loss)
    assert 1.0 < float(metrics["ce"]) < 12.0


@pytest.mark.parametrize("arch", ALL)
def test_grads_finite_nonzero(arch):
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    g = jax.jit(jax.grad(lambda p, b: loss_fn(p, b, cfg)[0]))(params, _batch(cfg))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                         for x in jax.tree.leaves(g)))
    assert jnp.isfinite(gnorm) and gnorm > 1e-4, (arch, gnorm)


@pytest.mark.parametrize("arch", ALL)
def test_decode_matches_forward(arch):
    """Prefill + N decode steps must equal the full-sequence forward pass."""
    cfg = _cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, extra = 2, 16, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + extra), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    mem = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model)
        ).astype(jnp.bfloat16)
        batch["frames"] = frames
        mem = _encode(params, frames, cfg)
    logits, cache = jax.jit(lambda p, b: prefill(p, b, cfg, max_len=S + extra))(
        params, batch
    )
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    for i in range(extra):
        logits, cache = step(params, toks[:, S + i: S + i + 1], cache)
    x, _, _ = jax.jit(lambda p, t: forward(p, t, cfg, memory=mem))(params, toks)
    want = L.unembed({"table": head_table(params)}, x[:, -1, :], cfg)
    err = np.abs(np.asarray(logits, np.float32) - np.asarray(want, np.float32)).max()
    scale = np.abs(np.asarray(want, np.float32)).max() + 1e-6
    assert err / scale < 1e-3, (arch, err, scale)


def test_rolling_cache_is_window_sized():
    from repro.models.model import init_cache

    cfg = _cfg("mixtral-8x7b")
    assert cfg.sliding_window == 64  # reduced
    cache = jax.eval_shape(lambda: init_cache(cfg, batch=2, max_len=512))
    k = jax.tree.leaves({"k": cache["layers"]["sub0"]["k"]})[0]
    assert k.shape[2] == 64, k.shape  # (G, B, window, Hk, hd)


def test_sliding_window_masks_distant_tokens():
    """Perturbing a token outside the window must not change the last logit."""
    cfg = _cfg("mixtral-8x7b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    S = 160  # > 2x window of 64
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab_size)
    toks2 = toks.at[0, 2].set((toks[0, 2] + 7) % cfg.vocab_size)
    f = jax.jit(lambda p, t: forward(p, t, cfg)[0][:, -1])
    a, b = f(params, toks), f(params, toks2)
    # mixtral interleaves full-attention? no: all layers SWA => identical
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=1e-6)


def test_gemma2_softcap_bounds_logits():
    cfg = _cfg("gemma2-2b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    x, _, _ = forward(params, _batch(cfg)["tokens"], cfg)
    logits = L.unembed({"table": head_table(params)}, x, cfg)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor the MoE output must differ from no-drop."""
    base = _cfg("mixtral-8x7b", exact_moe=False)
    tight = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=0.25)
    )
    loose = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=0.0)
    )
    params = init_params(jax.random.PRNGKey(0), loose)
    b = _batch(loose, B=4, S=64)
    xa, _, _ = forward(params, b["tokens"], tight)
    xb, _, _ = forward(params, b["tokens"], loose)
    assert np.abs(np.asarray(xa, np.float32) - np.asarray(xb, np.float32)).max() > 1e-4
